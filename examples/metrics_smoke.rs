//! Metrics smoke: launch a tiny dataflow with telemetry enabled, stream
//! a few messages through it, and print the coordinator's Prometheus
//! exposition to stdout — and nothing else, so CI can pipe the output
//! straight into `scripts/check_metrics.py`.
//!
//! ```sh
//! cargo run --release --example metrics_smoke \
//!   | python3 scripts/check_metrics.py
//! ```

use std::sync::{Arc, Mutex};
use std::time::Duration;

use floe::coordinator::{Coordinator, CoordinatorServer, RuntimeOptions};
use floe::graph::{GraphBuilder, SplitMode};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::Message;
use floe::pellet::builtins::CollectSink;
use floe::pellet::PelletRegistry;
use floe::telemetry::TelemetryConfig;
use floe::util::http::http_get;

fn main() {
    floe::util::logging::init();

    let registry = PelletRegistry::with_builtins();
    let collected = Arc::new(Mutex::new(Vec::new()));
    let c2 = Arc::clone(&collected);
    registry.register("demo.Collect", move || {
        Box::new(CollectSink { collected: Arc::clone(&c2) })
    });

    let mut g = GraphBuilder::new("metrics_smoke");
    g.pellet("up", "floe.builtin.Uppercase")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin);
    g.pellet("sink", "demo.Collect").in_port("in");
    g.edge("up", "out", "sink", "in");

    let coord = Coordinator::new(
        ResourceManager::new(SimulatedCloud::new(8, Duration::ZERO)),
        registry,
    );
    // Sample every batch so even this tiny run fills the e2e latency
    // histogram (the default 1-in-128 would likely see nothing here).
    let run = Arc::new(
        coord
            .launch(
                g.build().expect("valid graph"),
                RuntimeOptions::new()
                    .telemetry(TelemetryConfig::new().sample_every(1)),
            )
            .expect("launch"),
    );

    for i in 0..64 {
        run.inject("up", "in", Message::text(format!("msg {i}")))
            .expect("inject");
    }
    assert!(run.drain(Duration::from_secs(10)), "drain timed out");
    assert_eq!(collected.lock().unwrap().len(), 64);

    let mut server =
        CoordinatorServer::start(Arc::clone(&run), 0).expect("serve");
    let text =
        http_get(&server.addr(), "/metrics").expect("GET /metrics");
    print!("{text}");

    server.shutdown();
    run.stop();
}
