//! E2 — the end-to-end driver: distributed online stream clustering
//! (Fig. 3b) with the numeric hot-spots running as **AOT-compiled
//! JAX/Pallas kernels through PJRT** — all three layers composing on a
//! real workload.
//!
//! Streams synthetic topic-mixture posts through
//! TextCleaning → Bucketizer (XLA LSH) → ClusterSearch (XLA distance) →
//! Aggregator (XLA centroid update + feedback loop), reports throughput /
//! latency, and checks clustering quality (same-topic posts co-cluster
//! better than chance).
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example stream_clustering
//! ```

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use floe::apps::clustering::{self, text};
use floe::coordinator::{Coordinator, RuntimeOptions};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::{Landmark, Message};
use floe::pellet::builtins::CollectSink;
use floe::pellet::PelletRegistry;
use floe::runtime::{default_artifact_dir, XlaRuntime};

const POSTS: usize = 4096;

fn main() {
    floe::util::logging::init();

    // Load the AOT artifacts (L1 Pallas kernels lowered through the L2
    // JAX model into HLO text, compiled here by PJRT).
    let rt = Arc::new(
        XlaRuntime::load(default_artifact_dir())
            .expect("run `make artifacts` first"),
    );
    println!(
        "runtime: {} kernels on {}",
        rt.kernel_names().len(),
        rt.platform_name()
    );
    let params =
        clustering::ClusterParams::from_manifest(&rt.manifest).unwrap();
    println!(
        "model: batch={} dim={} bands={}x{} clusters={}",
        params.batch,
        params.dim,
        params.n_bands,
        params.band_width,
        params.n_clusters
    );
    let model = clustering::ClusterModel::new_random(params, 7);

    let registry = PelletRegistry::with_builtins();
    clustering::register(&registry, Arc::clone(&rt), Arc::clone(&model));
    let assignments = Arc::new(Mutex::new(Vec::new()));
    let a2 = Arc::clone(&assignments);
    registry.register("demo.Collect", move || {
        Box::new(CollectSink { collected: Arc::clone(&a2) })
    });

    let coord = Coordinator::new(
        ResourceManager::new(SimulatedCloud::tsangpo()),
        registry,
    );
    // Fig. 3b topology: 2 bucketizers, 3 cluster-search pellets.
    let mut graph =
        clustering::clustering_graph(params.batch, 2, 3).unwrap();
    // Tap the aggregator output into a collecting sink.
    graph.pellets.push({
        let mut p = floe::graph::PelletSpec::new("tap", "demo.Collect");
        p.inputs.push(floe::graph::InPortSpec {
            name: "in".into(),
            window: floe::graph::WindowSpec::None,
        });
        p
    });
    graph.edges.push(floe::graph::EdgeSpec::new(
        "aggregate",
        "out",
        "tap",
        "in",
    ));
    let run = coord.launch(graph, RuntimeOptions::new()).expect("launch");

    // Stream posts, remembering each post's true topic (generation order
    // == aggregator processing order is NOT guaranteed, so tag via text).
    let mut gen = clustering::PostGen::new(99);
    let mut truth: Vec<usize> = Vec::with_capacity(POSTS);
    let start = Instant::now();
    for _ in 0..POSTS {
        let (topic, post) = gen.post();
        truth.push(topic);
        run.inject("clean", "in", Message::text(post)).unwrap();
    }
    run.inject(
        "clean",
        "in",
        Message::landmark(Landmark::WindowEnd("flush".into())),
    )
    .unwrap();
    let drained = run.drain(Duration::from_secs(180));
    let secs = start.elapsed().as_secs_f64();

    let assigned = assignments
        .lock()
        .unwrap()
        .iter()
        .filter(|m| !m.is_landmark())
        .count();
    println!(
        "clustered {assigned}/{POSTS} posts in {secs:.2}s \
         ({:.0} posts/s), {} model updates, drained={drained}",
        assigned as f64 / secs,
        model.update_count()
    );
    assert!(drained && assigned == POSTS, "posts lost in flight");

    // Quality check: re-assign a fresh sample of posts per topic through
    // the trained model and measure intra-topic cluster agreement.
    let mut per_topic: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut gen2 = clustering::PostGen::new(1234);
    let mut sample: Vec<(usize, Vec<f32>)> = Vec::new();
    while sample.len() < 256 {
        let (topic, post) = gen2.post();
        sample.push((topic, text::featurize(&post, params.dim)));
    }
    for chunk in sample.chunks(params.batch) {
        let xs: Vec<Vec<f32>> =
            chunk.iter().map(|(_, v)| v.clone()).collect();
        let assigns = model.assign(&rt, &xs).unwrap();
        for ((topic, _), (cluster, _)) in chunk.iter().zip(assigns) {
            per_topic.entry(*topic).or_default().push(cluster);
        }
    }
    // For each topic: fraction of posts landing in that topic's modal
    // cluster.  Random assignment would give ~1/n_clusters.
    let mut purity_sum = 0.0;
    let mut topics = 0;
    for (topic, clusters) in &per_topic {
        let mut freq: HashMap<usize, usize> = HashMap::new();
        for c in clusters {
            *freq.entry(*c).or_default() += 1;
        }
        let modal = freq.values().max().copied().unwrap_or(0);
        let purity = modal as f64 / clusters.len() as f64;
        purity_sum += purity;
        topics += 1;
        println!(
            "  topic {topic}: {} posts, modal-cluster purity {purity:.2}",
            clusters.len()
        );
    }
    let mean_purity = purity_sum / topics as f64;
    let chance = 1.0 / params.n_clusters as f64;
    println!(
        "mean intra-topic purity {mean_purity:.2} (chance {chance:.2})"
    );
    assert!(
        mean_purity > 3.0 * chance,
        "clustering no better than chance"
    );
    run.stop();
    println!("stream_clustering OK");
}
