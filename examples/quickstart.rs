//! Quickstart: compose a small continuous dataflow with the builder API,
//! launch it through the coordinator on the simulated cloud, stream
//! messages through it, and read the results.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::{Arc, Mutex};
use std::time::Duration;

use floe::coordinator::{Coordinator, RuntimeOptions};
use floe::graph::{patterns, GraphBuilder, SplitMode};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::{Landmark, Message};
use floe::pellet::builtins::CollectSink;
use floe::pellet::PelletRegistry;

fn main() {
    floe::util::logging::init();

    // 1. A registry of pellet classes: builtins plus a custom sink that
    //    collects results for printing.
    let registry = PelletRegistry::with_builtins();
    let results = Arc::new(Mutex::new(Vec::new()));
    let r2 = Arc::clone(&results);
    registry.register("demo.Collect", move || {
        Box::new(CollectSink { collected: Arc::clone(&r2) })
    });

    // 2. Compose: source -> streaming word count (3 mappers, 2 reducers
    //    over the key-hash shuffle) -> sink.
    let mut g = GraphBuilder::new("quickstart");
    g.pellet("ingest", "floe.builtin.Identity")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin);
    let mr = patterns::map_reduce(
        &mut g,
        "wc",
        "floe.builtin.WordSplit",
        "floe.builtin.KeyCount",
        3,
        2,
    );
    for m in &mr.mappers {
        g.edge("ingest", "out", m, "in");
    }
    g.pellet("sink", "demo.Collect").in_port("in");
    for r in &mr.reducers {
        g.edge(r, "out", "sink", "in");
    }
    let graph = g.build().expect("valid graph");

    // 3. Launch on the simulated Eucalyptus cloud (16 nodes x 8 cores).
    let coord = Coordinator::new(
        ResourceManager::new(SimulatedCloud::tsangpo()),
        registry,
    );
    let run = coord.launch(graph, RuntimeOptions::new()).expect("launch");

    // 4. Stream text through, then close the logical window with a
    //    landmark so the streaming reducers emit their counts.
    for line in [
        "floe is a continuous dataflow framework",
        "dataflow applications are always on",
        "continuous dataflow meets elastic clouds",
    ] {
        run.inject("ingest", "in", Message::text(line)).unwrap();
    }
    run.drain(Duration::from_secs(10));
    run.inject(
        "ingest",
        "in",
        Message::landmark(Landmark::WindowEnd("w0".into())),
    )
    .unwrap();
    run.drain(Duration::from_secs(10));

    // 5. Print the word counts.
    let mut counts: Vec<String> = results
        .lock()
        .unwrap()
        .iter()
        .filter(|m| !m.is_landmark())
        .map(|m| m.as_text().unwrap().to_string())
        .collect();
    counts.sort();
    println!("word counts ({} distinct):", counts.len());
    for c in &counts {
        println!("  {c}");
    }
    assert!(counts.iter().any(|c| c == "dataflow=3"));
    run.stop();
    println!("quickstart OK");
}
