//! E3–E6 — regenerate the paper's Fig. 4 simulation study: the three
//! resource-adaptation strategies under periodic, periodic-with-spikes and
//! random-walk data rates, writing the time series (queue length and
//! allocated cores — the two panels of Fig. 4) as CSVs plus a summary
//! table with the cumulative-resource ratio (§IV-C: 0.87 : 1.00 : 0.98).
//!
//! ```sh
//! cargo run --release --example adaptation_sim -- [out_dir]
//! ```

use floe::sim::{
    compare_strategies, SimConfig, WorkloadProfile,
};

fn main() {
    floe::util::logging::init();
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "fig4_out".into());
    std::fs::create_dir_all(&out_dir).expect("mkdir");

    let cfg = SimConfig { duration: 3000.0, ..SimConfig::default() };
    let profiles = [
        WorkloadProfile::periodic_default(100.0),
        WorkloadProfile::spikes_default(100.0),
        WorkloadProfile::random_default(60.0),
    ];

    println!(
        "{:<10} {:<10} {:>12} {:>6} {:>12} {:>11} {:>9}",
        "profile", "strategy", "core-secs", "peak", "mean-drain",
        "violations", "final-q"
    );
    for profile in profiles {
        let (results, ratios) = compare_strategies(profile.clone(), &cfg);
        for r in &results {
            println!(
                "{:<10} {:<10} {:>12.0} {:>6} {:>12.1} {:>11} {:>9.0}",
                r.profile,
                r.strategy,
                r.core_seconds,
                r.peak_cores,
                r.mean_drain(),
                r.latency_violations,
                r.final_queue
            );
            let path = format!(
                "{out_dir}/fig4_{}_{}.csv",
                r.profile, r.strategy
            );
            r.to_csv().save(&path).expect("write csv");
        }
        println!(
            "{:<10} cumulative resource ratio s:d:h = \
             {:.2} : {:.2} : {:.2}   (paper, random: 0.87 : 1.00 : 0.98)",
            profile.name(),
            ratios[0],
            ratios[1],
            ratios[2]
        );
    }
    println!("CSV series written to {out_dir}/");
    println!("adaptation_sim OK");
}
