//! E7 — application dynamism (§II-B): update a pellet's logic **in place**
//! while the stream is flowing, in all three modes the paper describes:
//! asynchronous (zero downtime), synchronous (bounded by in-flight work,
//! with an update landmark), and the cascading wave update over a
//! sub-graph.
//!
//! ```sh
//! cargo run --release --example dynamic_update
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use floe::coordinator::{Coordinator, RuntimeOptions};
use floe::error::Result;
use floe::graph::{GraphBuilder, SplitMode};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::{Landmark, Message};
use floe::pellet::builtins::CollectSink;
use floe::pellet::{Pellet, PelletContext, PelletRegistry, PortIo};

struct Tag(&'static str);

impl Pellet for Tag {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                ctx.emit("out", m.clone());
            } else if let Some(t) = m.as_text() {
                ctx.emit("out", Message::text(format!("{}:{t}", self.0)));
            }
        }
        Ok(())
    }
}

fn main() {
    floe::util::logging::init();
    let registry = PelletRegistry::with_builtins();
    registry.register("demo.V1", || Box::new(Tag("v1")));
    registry.register("demo.V2", || Box::new(Tag("v2")));
    let out = Arc::new(Mutex::new(Vec::new()));
    let o2 = Arc::clone(&out);
    registry.register("demo.Collect", move || {
        Box::new(CollectSink { collected: Arc::clone(&o2) })
    });

    let coord = Coordinator::new(
        ResourceManager::new(SimulatedCloud::tsangpo()),
        registry,
    );
    let mut g = GraphBuilder::new("dyn");
    g.pellet("stage1", "demo.V1")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .stateful();
    g.pellet("stage2", "demo.V1")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .stateful();
    g.pellet("sink", "demo.Collect").in_port("in");
    g.edge("stage1", "out", "stage2", "in");
    g.edge("stage2", "out", "sink", "in");
    let run = Arc::new(
        coord.launch(g.build().unwrap(), RuntimeOptions::new()).unwrap(),
    );

    // Continuous injection in the background — the stream never stops.
    let stop = Arc::new(AtomicBool::new(false));
    let injector = {
        let run = Arc::clone(&run);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::SeqCst) {
                run.inject("stage1", "in", Message::text(format!("m{i}")))
                    .unwrap();
                i += 1;
                std::thread::sleep(Duration::from_micros(100));
            }
            i
        })
    };
    std::thread::sleep(Duration::from_millis(50));

    // 1. Asynchronous update of stage1: zero downtime, outputs of old and
    //    new logic may interleave.
    let t = Instant::now();
    let v = run.update_pellet("stage1", Some("demo.V2"), false, false).unwrap();
    println!(
        "async update of stage1 -> version {v} in {:?} (zero pause)",
        t.elapsed()
    );
    std::thread::sleep(Duration::from_millis(50));

    // 2. Synchronous update of stage2 with an update landmark: in-flight
    //    messages finish first, downstream is notified.
    let t = Instant::now();
    let v = run.update_pellet("stage2", Some("demo.V2"), true, true).unwrap();
    println!(
        "sync update of stage2 -> version {v} in {:?} (drained in-flight)",
        t.elapsed()
    );
    std::thread::sleep(Duration::from_millis(50));

    // 3. Wave update of the whole sub-graph back to V1, upstream-first,
    //    landmark at each hop.
    let t = Instant::now();
    let versions = run
        .wave_update(&[
            ("stage1".to_string(), "demo.V1".to_string()),
            ("stage2".to_string(), "demo.V1".to_string()),
        ])
        .unwrap();
    println!("wave update -> versions {versions:?} in {:?}", t.elapsed());

    stop.store(true, Ordering::SeqCst);
    let injected = injector.join().unwrap();
    assert!(run.drain(Duration::from_secs(30)));

    let got = out.lock().unwrap();
    let data: Vec<&str> = got
        .iter()
        .filter(|m| !m.is_landmark())
        .map(|m| m.as_text().unwrap())
        .collect();
    let landmarks = got
        .iter()
        .filter(|m| {
            matches!(m.landmark, Some(Landmark::Update { .. }))
        })
        .count();
    println!(
        "{} messages injected, {} delivered, {} update landmarks, 0 lost",
        injected,
        data.len(),
        landmarks
    );
    assert_eq!(data.len() as u64, injected, "message loss during updates");
    assert!(landmarks >= 1);
    // All four logic combinations existed at some point in the stream.
    for tag in ["v1:v1:", "v2:v1:", "v2:v2:"] {
        assert!(
            data.iter().any(|d| d.starts_with(tag)),
            "expected phase {tag}"
        );
    }
    run.stop();
    println!("dynamic_update OK");
}
