//! Live graph surgery demo: while messages stream through a running
//! pipeline, insert a pellet into a live edge, remove another pellet,
//! and relocate a flake to a different container — zero message loss,
//! with the measured pause-to-resume downtime of every surgery
//! printed at the end.
//!
//! Run with: `cargo run --release --example live_surgery`

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use floe::coordinator::{Coordinator, RuntimeOptions};
use floe::error::Result;
use floe::graph::{
    EdgeSpec, GraphBuilder, InPortSpec, OutPortSpec, PelletSpec,
    SplitMode, WindowSpec,
};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::Message;
use floe::pellet::{Pellet, PelletContext, PelletRegistry, PortIo};
use floe::recompose::GraphDelta;

struct CountingSink {
    delivered: Arc<AtomicUsize>,
}

impl Pellet for CountingSink {
    fn compute(
        &mut self,
        input: PortIo,
        _ctx: &mut PelletContext,
    ) -> Result<()> {
        let n = input
            .messages()
            .iter()
            .filter(|m| !m.is_landmark())
            .count();
        self.delivered.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }
}

fn audit_spec() -> PelletSpec {
    let mut s = PelletSpec::new("audit", "floe.builtin.Identity");
    s.inputs
        .push(InPortSpec { name: "in".into(), window: WindowSpec::None });
    s.outputs.push(OutPortSpec {
        name: "out".into(),
        split: SplitMode::RoundRobin,
    });
    s
}

fn main() {
    let cloud = SimulatedCloud::tsangpo();
    let registry = PelletRegistry::with_builtins();
    let delivered = Arc::new(AtomicUsize::new(0));
    let d2 = Arc::clone(&delivered);
    registry.register("demo.CountingSink", move || {
        Box::new(CountingSink { delivered: Arc::clone(&d2) })
    });
    let coord = Coordinator::new(ResourceManager::new(cloud), registry);

    // src -> work -> sink, continuously fed by a background injector.
    let mut g = GraphBuilder::new("surgery-demo");
    g.pellet("src", "floe.builtin.Identity")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin);
    g.pellet("work", "floe.builtin.Uppercase")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin);
    g.pellet("sink", "demo.CountingSink").in_port("in");
    g.edge("src", "out", "work", "in");
    g.edge("work", "out", "sink", "in");
    let run = Arc::new(
        coord
            .launch(g.build().unwrap(), RuntimeOptions::new())
            .unwrap(),
    );
    println!(
        "launched '{}' v{} with pellets {:?}",
        run.graph().name,
        run.graph_version(),
        run.pellet_ids()
    );

    let stop = Arc::new(AtomicBool::new(false));
    let injected = Arc::new(AtomicUsize::new(0));
    let injector = {
        let run = Arc::clone(&run);
        let stop = Arc::clone(&stop);
        let injected = Arc::clone(&injected);
        thread::spawn(move || {
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                run.inject("src", "in", Message::text(format!("m{i}")))
                    .unwrap();
                injected.fetch_add(1, Ordering::Relaxed);
                i += 1;
                if i % 64 == 0 {
                    thread::sleep(Duration::from_micros(200));
                }
            }
        })
    };
    thread::sleep(Duration::from_millis(20));

    // Surgery 1: splice an audit tap into the live work -> sink edge.
    let mut d = GraphDelta::against(&run.graph());
    d.insert_on_edge(
        EdgeSpec::new("work", "out", "sink", "in"),
        audit_spec(),
        "in",
        "out",
    );
    let s = run.recompose(&d).unwrap();
    println!(
        "v{}: inserted 'audit' on work->sink  (downtime {:.2} ms)",
        s.graph_version, s.downtime_ms
    );

    // Surgery 2: retire the worker; src feeds the tap directly.
    thread::sleep(Duration::from_millis(20));
    let mut d = GraphDelta::against(&run.graph());
    d.remove_pellet("work").add_edge("src", "out", "audit", "in");
    let s = run.recompose(&d).unwrap();
    println!(
        "v{}: removed 'work', rewired src->audit (downtime {:.2} ms)",
        s.graph_version, s.downtime_ms
    );

    // Surgery 3: migrate the tap's flake to a different container.
    thread::sleep(Duration::from_millis(20));
    let before = run.container("audit").unwrap().id.clone();
    let mut d = GraphDelta::against(&run.graph());
    d.relocate_flake("audit");
    let s = run.recompose(&d).unwrap();
    println!(
        "v{}: relocated 'audit' {} -> {} (downtime {:.2} ms)",
        s.graph_version,
        before,
        run.container("audit").unwrap().id,
        s.downtime_ms
    );

    thread::sleep(Duration::from_millis(20));
    stop.store(true, Ordering::Relaxed);
    injector.join().unwrap();
    assert!(run.drain(Duration::from_secs(30)));
    let sent = injected.load(Ordering::Relaxed);
    let got = delivered.load(Ordering::Relaxed);
    println!("injected {sent}, delivered {got}, lost {}", sent - got);
    assert_eq!(sent, got, "message loss during surgery");
    println!("pellets now: {:?}", run.pellet_ids());
    run.stop();
}
