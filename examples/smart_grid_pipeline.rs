//! E1 — the Smart Grid information-integration pipeline (Fig. 3a) on
//! synthetic campus feeds: meter/sensor events, bulk CSV archives and
//! NOAA-style XML weather documents, ingested into the triple store with
//! dynamic resource adaptation enabled.
//!
//! ```sh
//! cargo run --release --example smart_grid_pipeline
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use floe::adaptation::DynamicStrategy;
use floe::apps::smartgrid;
use floe::coordinator::{Coordinator, RuntimeOptions};
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::Message;
use floe::pellet::PelletRegistry;

fn main() {
    floe::util::logging::init();

    let registry = PelletRegistry::with_builtins();
    let store = Arc::new(smartgrid::TripleStore::new());
    smartgrid::register(&registry, Arc::clone(&store));
    let coord = Coordinator::new(
        ResourceManager::new(SimulatedCloud::tsangpo()),
        registry,
    );
    let graph = smartgrid::integration_graph().expect("graph");
    println!(
        "pipeline pellets: {:?}",
        graph.pellets.iter().map(|p| p.id.as_str()).collect::<Vec<_>>()
    );
    // The paper runs this dataflow with the dynamic adaptation strategy by
    // default (§IV-A).
    let options = RuntimeOptions::new().adaptation(
        Box::new(|_| {
            Box::new(DynamicStrategy {
                min_cores: 1,
                ..DynamicStrategy::default()
            })
        }),
        Duration::from_millis(100),
    );
    let run = coord.launch(graph, options).expect("launch");

    // Mixed-frequency sources (§IV-A: 1/min meters to 1/day archives —
    // compressed here into one burst per source class).
    let mut gen = smartgrid::FeedGen::new(2026, 24);
    let start = Instant::now();
    let mut injected = 0usize;
    for round in 0..400 {
        for _ in 0..6 {
            run.inject("parse", "in", Message::text(gen.meter_event()))
                .unwrap();
            injected += 1;
        }
        for _ in 0..2 {
            run.inject("parse", "in", Message::text(gen.sensor_event()))
                .unwrap();
            injected += 1;
        }
        if round % 10 == 0 {
            run.inject("parse", "in", Message::text(gen.noaa_xml()))
                .unwrap();
            injected += 1;
        }
        if round % 100 == 0 {
            // Occasional bulk upload (selectivity 50).
            run.inject("parse", "in", Message::text(gen.csv_archive(50)))
                .unwrap();
            injected += 1;
        }
    }
    let drained = run.drain(Duration::from_secs(60));
    let secs = start.elapsed().as_secs_f64();

    let ingested = run
        .flake("progress")
        .unwrap()
        .state()
        .get("ingested")
        .and_then(|j| j.as_f64())
        .unwrap_or(0.0);
    println!("injected {injected} source messages in {secs:.2}s");
    println!(
        "ingested {ingested} records -> {} triples in store \
         ({:.0} records/s), drained={drained}",
        store.len(),
        ingested / secs
    );
    println!(
        "sample kwh triples: {:?}",
        store
            .query(None, Some("grid:kwh"), None)
            .iter()
            .take(3)
            .map(|t| format!("{} {} {}", t.subject, t.predicate, t.object))
            .collect::<Vec<_>>()
    );
    assert!(drained, "pipeline failed to drain");
    assert!(store.len() > 100);
    run.stop();
    println!("smart_grid_pipeline OK");
}
