//! Discrete-time simulator for the §IV-C validation of the resource
//! adaptation strategies (Fig. 4).
//!
//! Simulates the representative pellet (`I1` of the integration pipeline)
//! under the three workload profiles, driving the *same*
//! [`AdaptationStrategy`](crate::adaptation::AdaptationStrategy)
//! implementations the live runtime uses.  Each second: arrivals enter the
//! queue, `cores × α` instances drain it at the pellet's service latency,
//! and every `sample_interval` the strategy re-decides the allocation.
//!
//! Outputs time series (queue length + allocated cores — the two panels of
//! Fig. 4) plus summary metrics: drain latency per period against the
//! `burst + ε` threshold, peak cores, and cumulative core-seconds (the
//! "area under the curve" whose static:dynamic:hybrid ratio the paper
//! reports as 0.87 : 1.00 : 0.98).

pub mod driver;
pub mod workload;

pub use driver::{
    register_driven, DrivenSource, LockstepDriver, ModeledFlake,
};
pub use workload::{WorkloadGen, WorkloadProfile};

use crate::adaptation::{
    AdaptationStrategy, DynamicStrategy, HybridStrategy, StaticLookAhead,
};
use crate::flake::FlakeObservation;
use crate::util::csv::CsvTable;
use crate::ALPHA;

/// Simulated pellet parameters (the paper's Fig. 3a annotations give the
/// shape; exact numbers are documented in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct SimPellet {
    /// Per-message service latency with one instance, seconds.
    pub latency: f64,
    /// Outputs per input (not used by the single-pellet sim but kept for
    /// pipeline-level extensions).
    pub selectivity: f64,
}

impl Default for SimPellet {
    fn default() -> Self {
        // I1: event-stream pellet, 100 ms/message, selectivity 1.
        SimPellet { latency: 0.1, selectivity: 1.0 }
    }
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub pellet: SimPellet,
    /// Total simulated seconds.
    pub duration: f64,
    /// Simulation step, seconds.
    pub dt: f64,
    /// Strategy sampling interval, seconds.
    pub sample_interval: f64,
    /// Latency tolerance ε, seconds (paper: 20 s).
    pub epsilon: f64,
    /// Instances per core.
    pub alpha: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            pellet: SimPellet::default(),
            duration: 1800.0,
            dt: 1.0,
            sample_interval: 5.0,
            epsilon: 20.0,
            alpha: ALPHA,
            seed: 42,
        }
    }
}

/// One sample of the simulated series.
#[derive(Debug, Clone, Copy)]
pub struct SimSample {
    pub t: f64,
    pub arrival_rate: f64,
    pub queue_len: f64,
    pub cores: usize,
    pub processed: f64,
}

/// Result of one (profile, strategy) simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub strategy: &'static str,
    pub profile: &'static str,
    pub samples: Vec<SimSample>,
    /// Σ cores·dt — the paper's "area under the curve" resource measure.
    pub core_seconds: f64,
    pub peak_cores: usize,
    /// Final queue length (divergence indicator for the random profile).
    pub final_queue: f64,
    /// Largest queue observed.
    pub peak_queue: f64,
    /// Per-period drain latency (seconds from period start until the queue
    /// empties after the burst), for periodic profiles.
    pub drain_latencies: Vec<f64>,
    /// Per-period worst message queueing delay (FIFO wait), seconds —
    /// the quantity the user's ε tolerance bounds.
    pub max_delays: Vec<f64>,
    /// Worst queueing delay over the whole run (random profiles report
    /// this instead of per-period numbers).
    pub max_delay: f64,
    /// Count of periods whose worst queueing delay exceeded ε.
    pub latency_violations: usize,
    /// The `burst + ε` display threshold (0 for random profiles).
    pub latency_threshold: f64,
}

impl SimResult {
    /// Mean drain latency over completed periods.
    pub fn mean_drain(&self) -> f64 {
        if self.drain_latencies.is_empty() {
            return 0.0;
        }
        self.drain_latencies.iter().sum::<f64>()
            / self.drain_latencies.len() as f64
    }

    /// Export the Fig. 4 series as CSV (t, arrival_rate, queue, cores).
    pub fn to_csv(&self) -> CsvTable {
        let mut t =
            CsvTable::new(&["t", "arrival_rate", "queue", "cores"]);
        for s in &self.samples {
            t.push(vec![
                format!("{:.1}", s.t),
                format!("{:.2}", s.arrival_rate),
                format!("{:.1}", s.queue_len),
                s.cores.to_string(),
            ]);
        }
        t
    }
}

/// Which strategy to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Static,
    Dynamic,
    Hybrid,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 3] =
        [StrategyKind::Static, StrategyKind::Dynamic, StrategyKind::Hybrid];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Static => "static",
            StrategyKind::Dynamic => "dynamic",
            StrategyKind::Hybrid => "hybrid",
        }
    }
}

/// Build the strategy for a profile the way the paper's user would: static
/// and hybrid get the oracle hints derived from the profile's *nominal*
/// parameters; dynamic gets nothing.
fn build_strategy(
    kind: StrategyKind,
    profile: &WorkloadProfile,
    cfg: &SimConfig,
) -> Box<dyn AdaptationStrategy> {
    // Oracle hint: messages per burst at the nominal rate, to be processed
    // within burst + ε (the paper's Fig. 4a "threshold of 80 secs").
    let (_, burst) = profile.period_burst().unwrap_or((300.0, 300.0));
    let m_per_burst = profile.burst_rate() * burst;
    let static_cores = StaticLookAhead::for_pellet(
        cfg.pellet.latency,
        m_per_burst,
        burst,
        cfg.epsilon,
        cfg.alpha,
    )
    .cores;
    match kind {
        StrategyKind::Static => {
            Box::new(StaticLookAhead { cores: static_cores })
        }
        StrategyKind::Dynamic => Box::new(DynamicStrategy {
            alpha: cfg.alpha,
            ..DynamicStrategy::default()
        }),
        StrategyKind::Hybrid => Box::new(HybridStrategy::new(
            static_cores,
            profile.burst_rate(),
            0.35,
        )),
    }
}

/// Run one simulation.
pub fn simulate(
    profile: WorkloadProfile,
    kind: StrategyKind,
    cfg: &SimConfig,
) -> SimResult {
    let mut strategy = build_strategy(kind, &profile, cfg);
    let mut gen = WorkloadGen::new(profile.clone(), cfg.seed);

    let mut queue: f64 = 0.0;
    let mut cores: usize = match kind {
        // Static allocation is fixed from t=0 (the "oracle" user asked for
        // it at submission); others start at 0 and adapt.
        StrategyKind::Static => {
            strategy
                .decide(&dummy_obs(0.0, 0.0, cfg.pellet.latency, 0), 0.0)
        }
        _ => 0,
    };
    let mut samples = Vec::new();
    let mut core_seconds = 0.0;
    let mut peak_cores = 0usize;
    let mut peak_queue = 0.0f64;

    // Rate estimation window for the strategy observation (mirrors the
    // live probes' behaviour).
    let mut arr_window: Vec<(f64, f64)> = Vec::new(); // (t, cumulative)
    let mut cum_arrivals = 0.0;
    let mut next_sample = 0.0;

    // Drain-latency + queueing-delay bookkeeping.
    let period_burst = profile.period_burst();
    let mut drain_latencies = Vec::new();
    let mut max_delays = Vec::new();
    let mut period_start = 0.0;
    let mut seen_data_this_period = false;
    let mut period_max_delay = 0.0f64;
    let mut run_max_delay = 0.0f64;
    let mut drained_at: Option<f64> = None;
    // FIFO of (arrival time, messages) buckets for per-message delay.
    let mut fifo: std::collections::VecDeque<(f64, f64)> =
        std::collections::VecDeque::new();

    let steps = (cfg.duration / cfg.dt).ceil() as usize;
    for step in 0..steps {
        let t = step as f64 * cfg.dt;

        // Period rollover bookkeeping.
        if let Some((period, _)) = period_burst {
            if t - period_start >= period {
                if seen_data_this_period {
                    drain_latencies
                        .push(drained_at.unwrap_or(period));
                    max_delays.push(period_max_delay);
                }
                period_start = t;
                seen_data_this_period = false;
                period_max_delay = 0.0;
                drained_at = None;
            }
        }

        // Arrivals.
        let arrivals = gen.arrivals(t, cfg.dt);
        cum_arrivals += arrivals;
        if arrivals > 0.0 {
            seen_data_this_period = true;
            drained_at = None; // still receiving, not drained
            fifo.push_back((t, arrivals));
        }
        queue += arrivals;

        // Service: drain the FIFO, tracking the worst per-message wait.
        let capacity = (cores * cfg.alpha) as f64 * cfg.dt
            / cfg.pellet.latency.max(1e-9);
        let processed = queue.min(capacity);
        queue -= processed;
        let mut left = processed;
        while left > 0.0 {
            let Some(front) = fifo.front_mut() else { break };
            let take = front.1.min(left);
            front.1 -= take;
            left -= take;
            let delay = t - front.0;
            period_max_delay = period_max_delay.max(delay);
            run_max_delay = run_max_delay.max(delay);
            if front.1 <= 0.0 {
                fifo.pop_front();
            }
        }
        // Unprocessed backlog also ages: count waiting time of the oldest
        // queued message so far (a period that never drains still shows
        // its true worst-case delay).
        if let Some(&(t0, _)) = fifo.front() {
            let waiting = t - t0;
            period_max_delay = period_max_delay.max(waiting);
            run_max_delay = run_max_delay.max(waiting);
        }
        if queue <= 0.5 && seen_data_this_period && drained_at.is_none() {
            drained_at = Some(t - period_start);
        }

        // Strategy sampling.
        arr_window.push((t, cum_arrivals));
        if arr_window.len() > 5 {
            let excess = arr_window.len() - 5;
            arr_window.drain(..excess);
        }
        if t >= next_sample {
            next_sample += cfg.sample_interval;
            let arrival_rate = window_rate(&arr_window);
            let obs = dummy_obs(
                queue,
                arrival_rate,
                cfg.pellet.latency,
                cores,
            );
            let decided = strategy.decide(&obs, t);
            if kind != StrategyKind::Static {
                cores = decided;
            }
        }

        core_seconds += cores as f64 * cfg.dt;
        peak_cores = peak_cores.max(cores);
        peak_queue = peak_queue.max(queue);
        samples.push(SimSample {
            t,
            arrival_rate: arrivals / cfg.dt,
            queue_len: queue,
            cores,
            processed,
        });
    }

    let latency_threshold = period_burst
        .map(|(_, burst)| burst + cfg.epsilon)
        .unwrap_or(0.0);
    // A period violates the user's tolerance when any message waited more
    // than ε in the queue (for the clean burst profile this matches the
    // paper's "drained by burst + ε" framing).
    let latency_violations = if period_burst.is_some() {
        max_delays.iter().filter(|&&d| d > cfg.epsilon).count()
    } else {
        0
    };

    SimResult {
        strategy: kind.name(),
        profile: profile.name(),
        samples,
        core_seconds,
        peak_cores,
        final_queue: queue,
        peak_queue,
        drain_latencies,
        max_delays,
        max_delay: run_max_delay,
        latency_violations,
        latency_threshold,
    }
}

fn window_rate(w: &[(f64, f64)]) -> f64 {
    if w.len() < 2 {
        return 0.0;
    }
    let (t0, a0) = w[0];
    let (t1, a1) = w[w.len() - 1];
    if t1 <= t0 {
        return 0.0;
    }
    (a1 - a0) / (t1 - t0)
}

fn dummy_obs(
    queue: f64,
    arrival_rate: f64,
    latency: f64,
    cores: usize,
) -> FlakeObservation {
    FlakeObservation {
        queue_len: queue.round() as usize,
        arrival_rate,
        completion_rate: 0.0,
        service_latency: latency,
        selectivity: 1.0,
        cores,
        instances: cores * ALPHA,
    }
}

/// Run all three strategies on a profile and report the cumulative
/// resource ratio normalized to dynamic = 1.00 (the paper's §IV-C metric).
pub fn compare_strategies(
    profile: WorkloadProfile,
    cfg: &SimConfig,
) -> (Vec<SimResult>, [f64; 3]) {
    let results: Vec<SimResult> = StrategyKind::ALL
        .iter()
        .map(|&k| simulate(profile.clone(), k, cfg))
        .collect();
    let dynamic_cs = results
        .iter()
        .find(|r| r.strategy == "dynamic")
        .map(|r| r.core_seconds)
        .unwrap_or(1.0)
        .max(1e-9);
    let ratios = [
        results[0].core_seconds / dynamic_cs,
        results[1].core_seconds / dynamic_cs,
        results[2].core_seconds / dynamic_cs,
    ];
    (results, ratios)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig { duration: 1500.0, ..SimConfig::default() }
    }

    #[test]
    fn periodic_static_meets_threshold() {
        let r = simulate(
            WorkloadProfile::periodic_default(100.0),
            StrategyKind::Static,
            &cfg(),
        );
        assert!(!r.drain_latencies.is_empty());
        // The oracle allocation drains each period within burst + ε.
        assert_eq!(
            r.latency_violations, 0,
            "drains: {:?}",
            r.drain_latencies
        );
        assert!(r.peak_cores >= 1);
    }

    #[test]
    fn periodic_dynamic_drains_and_quiesces() {
        let r = simulate(
            WorkloadProfile::periodic_default(100.0),
            StrategyKind::Dynamic,
            &cfg(),
        );
        assert_eq!(r.latency_violations, 0, "{:?}", r.drain_latencies);
        // Quiesces between bursts: some samples at 0 cores.
        assert!(r.samples.iter().any(|s| s.cores == 0));
        // And scales up during bursts.
        assert!(r.peak_cores >= 2);
    }

    #[test]
    fn spikes_static_misses_dynamic_holds() {
        let c = cfg();
        let rs = simulate(
            WorkloadProfile::spikes_default(100.0),
            StrategyKind::Static,
            &c,
        );
        let rd = simulate(
            WorkloadProfile::spikes_default(100.0),
            StrategyKind::Dynamic,
            &c,
        );
        // Paper Fig. 4 center: static misses the tolerance under spikes;
        // dynamic processes within tolerance with a larger peak.
        assert!(rs.latency_violations > 0, "static should miss");
        assert!(
            rd.latency_violations <= rs.latency_violations,
            "dynamic {} vs static {}",
            rd.latency_violations,
            rs.latency_violations
        );
        assert!(rd.peak_cores >= rs.peak_cores);
    }

    #[test]
    fn random_static_queue_grows_dynamic_bounded() {
        let c = SimConfig { duration: 3000.0, ..cfg() };
        let rs = simulate(
            WorkloadProfile::random_default(60.0),
            StrategyKind::Static,
            &c,
        );
        let rd = simulate(
            WorkloadProfile::random_default(60.0),
            StrategyKind::Dynamic,
            &c,
        );
        // Paper Fig. 4 right: static's queue accumulates over time while
        // dynamic keeps pending messages negligible.
        assert!(
            rs.peak_queue > 5.0 * rd.peak_queue.max(1.0),
            "static peak {} dynamic peak {}",
            rs.peak_queue,
            rd.peak_queue
        );
        assert!(rd.final_queue < 500.0, "dynamic final {}", rd.final_queue);
    }

    #[test]
    fn random_resource_ratio_shape() {
        let c = SimConfig { duration: 3000.0, ..cfg() };
        let (_results, ratios) =
            compare_strategies(WorkloadProfile::random_default(60.0), &c);
        // Paper: 0.87 : 1.00 : 0.98 — static slightly below dynamic,
        // hybrid between static and dynamic (within tolerance).
        assert!((ratios[1] - 1.0).abs() < 1e-9);
        assert!(
            ratios[0] > 0.6 && ratios[0] < 1.05,
            "static ratio {}",
            ratios[0]
        );
        assert!(
            ratios[2] > 0.7 && ratios[2] <= 1.15,
            "hybrid ratio {}",
            ratios[2]
        );
    }

    #[test]
    fn csv_export_has_all_samples() {
        let r = simulate(
            WorkloadProfile::periodic_default(50.0),
            StrategyKind::Dynamic,
            &SimConfig { duration: 100.0, ..SimConfig::default() },
        );
        let t = r.to_csv();
        assert_eq!(t.rows.len(), 100);
        assert_eq!(t.header, vec!["t", "arrival_rate", "queue", "cores"]);
    }

    #[test]
    fn deterministic_for_seed() {
        let c = cfg();
        let a = simulate(
            WorkloadProfile::random_default(40.0),
            StrategyKind::Hybrid,
            &c,
        );
        let b = simulate(
            WorkloadProfile::random_default(40.0),
            StrategyKind::Hybrid,
            &c,
        );
        assert_eq!(a.core_seconds, b.core_seconds);
        assert_eq!(a.final_queue, b.final_queue);
    }
}
