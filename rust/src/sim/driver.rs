//! Deterministic workload driving for a **live** dataflow: the §IV-C
//! profiles injected into real flakes at simulated-clock rates, so the
//! whole elasticity loop (observe → decide → regrant → relocate →
//! resume) runs under `cargo test` with no wall-clock flakiness.
//!
//! Three pieces, all seeded:
//!
//! * [`DrivenSource`] — a pellet (`floe.sim.DrivenSource`) that owns a
//!   [`WorkloadGen`]: every *tick* message it receives advances the
//!   simulated time by `dt` and emits that step's arrivals as
//!   sequence-numbered text messages (`w00000042`), so loss and
//!   per-producer FIFO are checkable downstream.
//! * [`LockstepDriver`] — the harness side: injects one tick per step,
//!   advances a shared [`VirtualClock`], and runs a *mirror*
//!   `WorkloadGen` with the same seed, so the expected message count
//!   (and the whole arrival series) is known exactly.
//! * [`ModeledFlake`] — a deterministic stand-in for the live probes
//!   (the Fig. 4 simulator's queue/service model): the elasticity
//!   policy reads observations from the model while its *actions* hit
//!   the live dataflow, which makes decision traces bit-reproducible
//!   per seed.
//!
//! `DrivenSource` reads its configuration from the flake's state
//! object on the first tick (set the keys right after launch, before
//! any tick is injected): `profile` (`periodic` | `spikes` | `random`),
//! `rate`, `seed`, `dt`, and optional `period` / `burst` overrides for
//! test-sized cycles.

use crate::coordinator::RunningDataflow;
use crate::error::Result;
use crate::flake::FlakeObservation;
use crate::message::Message;
use crate::pellet::{
    Pellet, PelletContext, PelletRegistry, PortIo, StateObject,
};
use crate::sim::workload::{WorkloadGen, WorkloadProfile};
use crate::util::time::VirtualClock;

/// Build a generator (plus the step size) from state-object keys.
fn configure(state: &StateObject) -> (WorkloadGen, f64) {
    let num = |key: &str, default: f64| {
        state.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    };
    let rate = num("rate", 100.0);
    let seed = num("seed", 42.0) as u64;
    let dt = num("dt", 1.0).max(1e-6);
    let name = state
        .get("profile")
        .and_then(|j| j.as_str().map(str::to_string))
        .unwrap_or_else(|| "periodic".to_string());
    let mut profile = match name.as_str() {
        "spikes" => WorkloadProfile::spikes_default(rate),
        "random" => WorkloadProfile::random_default(rate),
        _ => WorkloadProfile::periodic_default(rate),
    };
    match &mut profile {
        WorkloadProfile::Periodic { period, burst, .. }
        | WorkloadProfile::PeriodicSpikes { period, burst, .. } => {
            *period = num("period", *period);
            *burst = num("burst", *burst);
        }
        WorkloadProfile::RandomWalk { .. } => {}
    }
    (WorkloadGen::new(profile, seed), dt)
}

/// Seeded source pellet: one tick in, one simulated step of arrivals
/// out (see module docs).  Run it `sequential` so the emission order is
/// the sequence order.
///
/// The generator, simulated time and sequence counter live in the
/// pellet *instance*, not the state object: relocating or hot-swapping
/// the source resets the series to `w00000000` and diverges from the
/// mirror.  Drive the workload from a pellet the policy never touches
/// (the harness relocates downstream flakes only).
#[derive(Default)]
pub struct DrivenSource {
    gen: Option<WorkloadGen>,
    t: f64,
    dt: f64,
    seq: u64,
}

impl DrivenSource {
    pub fn new() -> DrivenSource {
        DrivenSource::default()
    }
}

impl Pellet for DrivenSource {
    fn compute(
        &mut self,
        input: PortIo,
        ctx: &mut PelletContext,
    ) -> Result<()> {
        if self.gen.is_none() {
            let (gen, dt) = configure(ctx.state());
            self.gen = Some(gen);
            self.dt = dt;
        }
        let gen = self.gen.as_mut().expect("just configured");
        for m in input.messages() {
            if m.is_landmark() {
                continue;
            }
            let n = gen.arrivals(self.t, self.dt) as u64;
            for _ in 0..n {
                ctx.emit(
                    "out",
                    Message::text(format!("w{:08}", self.seq)),
                );
                self.seq += 1;
            }
            self.t += self.dt;
        }
        Ok(())
    }
}

/// Register the driver pellet class (`floe.sim.DrivenSource`).
pub fn register_driven(registry: &PelletRegistry) {
    registry
        .register("floe.sim.DrivenSource", || Box::new(DrivenSource::new()));
}

/// Harness half of the deterministic loop (see module docs).
pub struct LockstepDriver {
    clock: VirtualClock,
    mirror: WorkloadGen,
    dt: f64,
    t: f64,
    expected: u64,
}

impl LockstepDriver {
    /// `profile`/`seed`/`dt` must match the [`DrivenSource`]'s state
    /// configuration, or the mirror diverges.
    pub fn new(
        profile: WorkloadProfile,
        seed: u64,
        dt: f64,
    ) -> LockstepDriver {
        LockstepDriver {
            clock: VirtualClock::new(),
            mirror: WorkloadGen::new(profile, seed),
            dt,
            t: 0.0,
            expected: 0,
        }
    }

    /// The shared simulated clock (advanced by [`LockstepDriver::step`]).
    pub fn clock(&self) -> VirtualClock {
        self.clock.clone()
    }

    /// Simulated time of the *next* step.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Total arrivals the source must have emitted so far.
    pub fn expected_total(&self) -> u64 {
        self.expected
    }

    /// Inject one tick into `source.port` and advance the simulated
    /// clock by `dt`.  Returns this step's arrival count (mirror).
    pub fn step(
        &mut self,
        run: &RunningDataflow,
        source: &str,
        port: &str,
    ) -> Result<u64> {
        let n = self.mirror.arrivals(self.t, self.dt) as u64;
        self.expected += n;
        run.inject(source, port, Message::text("tick"))?;
        self.t += self.dt;
        self.clock.advance_to(self.t);
        Ok(n)
    }
}

/// Deterministic queue/service model standing in for live probes (the
/// same shape as the Fig. 4 simulator): arrivals pile into a modeled
/// queue that `cores × alpha` instances drain at a fixed per-message
/// latency, and the arrival rate comes from a sliding sample window
/// exactly like [`crate::flake::Probes::sample_rates`].
pub struct ModeledFlake {
    pub latency: f64,
    pub alpha: usize,
    queue: f64,
    cum_arrivals: f64,
    window: Vec<(f64, f64)>,
}

impl ModeledFlake {
    pub fn new(latency: f64, alpha: usize) -> ModeledFlake {
        ModeledFlake {
            latency,
            alpha: alpha.max(1),
            queue: 0.0,
            cum_arrivals: 0.0,
            window: Vec::new(),
        }
    }

    /// Account one step: `arrivals` messages land during `dt` seconds
    /// while `cores` drain the queue.
    pub fn advance(
        &mut self,
        t: f64,
        dt: f64,
        arrivals: f64,
        cores: usize,
    ) {
        self.cum_arrivals += arrivals;
        self.queue += arrivals;
        let capacity = (cores * self.alpha) as f64 * dt
            / self.latency.max(1e-9);
        self.queue = (self.queue - capacity).max(0.0);
        self.window.push((t, self.cum_arrivals));
        if self.window.len() > 5 {
            let drop = self.window.len() - 5;
            self.window.drain(..drop);
        }
    }

    /// Observation for the adaptation strategy at the current state.
    pub fn observe(&self, cores: usize) -> FlakeObservation {
        let arrival_rate = if self.window.len() < 2 {
            0.0
        } else {
            let (t0, a0) = self.window[0];
            let (t1, a1) = self.window[self.window.len() - 1];
            if t1 > t0 {
                (a1 - a0) / (t1 - t0)
            } else {
                0.0
            }
        };
        FlakeObservation {
            queue_len: self.queue.round() as usize,
            arrival_rate,
            completion_rate: 0.0,
            service_latency: self.latency,
            selectivity: 1.0,
            cores,
            instances: cores * self.alpha,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn configure_reads_state_keys() {
        let state = StateObject::new();
        state.set("profile", Json::str("spikes"));
        state.set("rate", Json::num(200.0));
        state.set("seed", Json::num(9.0));
        state.set("dt", Json::num(0.5));
        state.set("period", Json::num(40.0));
        state.set("burst", Json::num(20.0));
        let (_gen, dt) = configure(&state);
        assert!((dt - 0.5).abs() < 1e-12);
        // Mirror with identical parameters produces the same series.
        let mut profile = WorkloadProfile::spikes_default(200.0);
        if let WorkloadProfile::PeriodicSpikes { period, burst, .. } =
            &mut profile
        {
            *period = 40.0;
            *burst = 20.0;
        }
        let mut a = configure(&state).0;
        let mut b = WorkloadGen::new(profile, 9);
        for step in 0..200 {
            let t = step as f64 * 0.5;
            assert_eq!(
                a.arrivals(t, 0.5).to_bits(),
                b.arrivals(t, 0.5).to_bits()
            );
        }
    }

    #[test]
    fn modeled_flake_conserves_queue() {
        let mut m = ModeledFlake::new(0.1, 4);
        // 100 msgs/step vs capacity 40/step at 1 core -> queue grows
        // by 60/step.
        for step in 0..10 {
            m.advance(step as f64, 1.0, 100.0, 1);
        }
        let obs = m.observe(1);
        assert_eq!(obs.queue_len, 600);
        assert!((obs.arrival_rate - 100.0).abs() < 1e-9);
        // 5 cores drain 200/step: queue shrinks.
        for step in 10..13 {
            m.advance(step as f64, 1.0, 100.0, 5);
        }
        assert_eq!(m.observe(5).queue_len, 300);
    }
}
