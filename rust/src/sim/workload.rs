//! Workload profiles for the §IV-C simulation study: *periodic* with a
//! constant data rate, *periodic with random spikes*, and a *random walk*
//! with a known long-term average — the three profiles "observed in our
//! applications".

use crate::util::rng::Rng;

/// Message arrival profile: rate (msg/s) as a function of time.
#[derive(Debug, Clone)]
pub enum WorkloadProfile {
    /// Bursts of `rate` msg/s for `burst` seconds every `period` seconds,
    /// silent in between (paper: period 5 min, data duration 60 s).
    Periodic { rate: f64, period: f64, burst: f64 },
    /// Periodic plus random spikes: with probability `spike_prob` per
    /// second during a burst a surge starts, multiplying the rate by
    /// `spike_mult` for `spike_len` seconds; surges can also fire in the
    /// gap with probability `spike_prob / 4`.
    PeriodicSpikes {
        rate: f64,
        period: f64,
        burst: f64,
        spike_prob: f64,
        spike_mult: f64,
        spike_len: f64,
    },
    /// One-dimensional random walk around `mean` with per-step standard
    /// deviation `step`, clamped to `[min, max]` — slow variation with a
    /// known long-term average.
    RandomWalk { mean: f64, step: f64, min: f64, max: f64 },
}

impl WorkloadProfile {
    /// Paper defaults: 5-minute period, 60-second data burst.
    pub fn periodic_default(rate: f64) -> WorkloadProfile {
        WorkloadProfile::Periodic { rate, period: 300.0, burst: 60.0 }
    }

    pub fn spikes_default(rate: f64) -> WorkloadProfile {
        WorkloadProfile::PeriodicSpikes {
            rate,
            period: 300.0,
            burst: 60.0,
            spike_prob: 0.03,
            spike_mult: 2.0,
            spike_len: 10.0,
        }
    }

    pub fn random_default(mean: f64) -> WorkloadProfile {
        WorkloadProfile::RandomWalk {
            mean,
            step: mean * 0.08,
            min: 0.0,
            max: mean * 3.0,
        }
    }

    /// Profile name for CSV/labels.
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadProfile::Periodic { .. } => "periodic",
            WorkloadProfile::PeriodicSpikes { .. } => "spikes",
            WorkloadProfile::RandomWalk { .. } => "random",
        }
    }

    /// Long-term average rate — what the static "oracle" and hybrid hint
    /// are derived from.
    pub fn long_term_average(&self) -> f64 {
        match self {
            WorkloadProfile::Periodic { rate, period, burst } => {
                rate * burst / period
            }
            WorkloadProfile::PeriodicSpikes {
                rate,
                period,
                burst,
                ..
            } => rate * burst / period,
            WorkloadProfile::RandomWalk { mean, .. } => *mean,
        }
    }

    /// Peak nominal rate during a burst (no spikes).
    pub fn burst_rate(&self) -> f64 {
        match self {
            WorkloadProfile::Periodic { rate, .. }
            | WorkloadProfile::PeriodicSpikes { rate, .. } => *rate,
            WorkloadProfile::RandomWalk { mean, .. } => *mean,
        }
    }

    /// Period/burst parameters where meaningful.
    pub fn period_burst(&self) -> Option<(f64, f64)> {
        match self {
            WorkloadProfile::Periodic { period, burst, .. }
            | WorkloadProfile::PeriodicSpikes { period, burst, .. } => {
                Some((*period, *burst))
            }
            WorkloadProfile::RandomWalk { .. } => None,
        }
    }
}

/// Stateful arrival generator stepping a profile through time.
pub struct WorkloadGen {
    profile: WorkloadProfile,
    rng: Rng,
    /// Random-walk current rate.
    walk_rate: f64,
    /// Spike surge active until this time.
    spike_until: f64,
}

impl WorkloadGen {
    pub fn new(profile: WorkloadProfile, seed: u64) -> WorkloadGen {
        let walk_rate = profile.long_term_average();
        WorkloadGen {
            profile,
            rng: Rng::new(seed),
            walk_rate,
            spike_until: -1.0,
        }
    }

    /// Number of messages arriving in `[t, t+dt)`.
    pub fn arrivals(&mut self, t: f64, dt: f64) -> f64 {
        let rate = self.rate_at(t, dt);
        if rate <= 0.0 {
            return 0.0;
        }
        // Poisson arrivals at the instantaneous rate.
        self.rng.poisson(rate * dt) as f64
    }

    /// Instantaneous rate (also advances random-walk state).
    pub fn rate_at(&mut self, t: f64, dt: f64) -> f64 {
        match &self.profile {
            WorkloadProfile::Periodic { rate, period, burst } => {
                let phase = t % period;
                if phase < *burst {
                    *rate
                } else {
                    0.0
                }
            }
            WorkloadProfile::PeriodicSpikes {
                rate,
                period,
                burst,
                spike_prob,
                spike_mult,
                spike_len,
            } => {
                let phase = t % period;
                let (base, p) = if phase < *burst {
                    (*rate, *spike_prob)
                } else {
                    (0.0, *spike_prob / 4.0)
                };
                if t >= self.spike_until && self.rng.chance(p * dt) {
                    // A surge starts: elevated rate for spike_len secs.
                    self.spike_until = t + spike_len;
                }
                if t < self.spike_until {
                    (base + rate * 0.2) * spike_mult
                } else {
                    base
                }
            }
            WorkloadProfile::RandomWalk { mean, step, min, max } => {
                // Mean-reverting walk so the long-term average holds.
                let pull = 0.02 * (mean - self.walk_rate);
                self.walk_rate += pull + self.rng.normal() * step * dt.sqrt();
                self.walk_rate = self.walk_rate.clamp(*min, *max);
                self.walk_rate
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_is_on_off() {
        let mut g =
            WorkloadGen::new(WorkloadProfile::periodic_default(100.0), 1);
        assert_eq!(g.rate_at(10.0, 1.0), 100.0); // in burst
        assert_eq!(g.rate_at(100.0, 1.0), 0.0); // in gap
        assert_eq!(g.rate_at(310.0, 1.0), 100.0); // next period
    }

    #[test]
    fn periodic_average_matches() {
        let p = WorkloadProfile::periodic_default(100.0);
        assert!((p.long_term_average() - 20.0).abs() < 1e-9);
        let mut g = WorkloadGen::new(p, 2);
        let total: f64 = (0..3000).map(|t| g.arrivals(t as f64, 1.0)).sum();
        let avg = total / 3000.0;
        assert!((avg - 20.0).abs() < 3.0, "avg={avg}");
    }

    #[test]
    fn spikes_exceed_nominal_sometimes() {
        let mut g =
            WorkloadGen::new(WorkloadProfile::spikes_default(100.0), 3);
        let mut spiked = false;
        for t in 0..3000 {
            if g.rate_at(t as f64, 1.0) > 150.0 {
                spiked = true;
                break;
            }
        }
        assert!(spiked);
    }

    #[test]
    fn random_walk_reverts_to_mean() {
        let mut g =
            WorkloadGen::new(WorkloadProfile::random_default(50.0), 4);
        let rates: Vec<f64> =
            (0..5000).map(|t| g.rate_at(t as f64, 1.0)).collect();
        let avg = rates.iter().sum::<f64>() / rates.len() as f64;
        assert!((avg - 50.0).abs() < 15.0, "avg={avg}");
        assert!(rates.iter().all(|&r| (0.0..=150.0).contains(&r)));
        // it actually varies
        let min = rates.iter().cloned().fold(f64::MAX, f64::min);
        let max = rates.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max - min > 10.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = WorkloadProfile::spikes_default(80.0);
        let mut a = WorkloadGen::new(p.clone(), 9);
        let mut b = WorkloadGen::new(p, 9);
        for t in 0..500 {
            assert_eq!(a.arrivals(t as f64, 1.0), b.arrivals(t as f64, 1.0));
        }
    }
}
