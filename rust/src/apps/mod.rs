//! The paper's two case-study applications (§IV):
//!
//! * [`smartgrid`] — the Smart Grid information-integration pipeline
//!   (Fig. 3a): meter/sensor event streams, bulk CSV archives and
//!   NOAA-style XML weather documents parsed, semantically annotated and
//!   inserted into a triple store.
//! * [`clustering`] — distributed online stream clustering with LSH
//!   (Fig. 3b): text cleaning → LSH bucketizer → cluster search →
//!   aggregator with a feedback loop; the numeric hot-spots run as
//!   AOT-compiled JAX/Pallas kernels through PJRT.

pub mod clustering;
pub mod smartgrid;
