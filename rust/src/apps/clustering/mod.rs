//! Distributed online stream clustering with LSH (Fig. 3b).
//!
//! ```text
//! posts → T0 cl.TextCleaning → T1/T2 cl.Bucketizer ══keyhash══>
//!         T3..T5 cl.ClusterSearch → T6 cl.Aggregator → assignments
//!                     ↑───────────── feedback loop ────────┘
//! ```
//!
//! * `cl.TextCleaning` — stemming/stop-word/dictionary featurization.
//! * `cl.Bucketizer` — batches feature vectors and runs the **AOT Pallas
//!   LSH kernel** through PJRT; attaches the band-0 bucket id as the
//!   message key so Floe's *dynamic key-hash port mapping* groups similar
//!   posts onto the same ClusterSearch pellet (the paper's
//!   more-versatile-than-MapReduce routing).
//! * `cl.ClusterSearch` — batches candidates and runs the **AOT distance
//!   kernel** (masked argmin) against the shared centroids, acting as a
//!   local combiner.
//! * `cl.Aggregator` — finalizes the global best cluster, folds the batch
//!   into the model with the **centroid-update kernel**, emits
//!   `cluster=<k> d2=<dist>` assignments, and notifies the search pellets
//!   through the feedback-loop edge.
//!
//! Messages between Bucketizer → Aggregator carry `[vector.., idx, d2]`
//! as a flat f32 payload (documented wire contract of this app).

pub mod model;
pub mod text;

pub use model::{make_projection, ClusterModel, ClusterParams};
pub use text::{featurize, PostGen};

use std::sync::Arc;

use crate::error::Result;
use crate::graph::{
    DataflowGraph, GraphBuilder, SplitMode, WindowSpec,
};
use crate::message::Message;
use crate::pellet::{Pellet, PelletContext, PelletRegistry, PortIo};
use crate::runtime::XlaRuntime;

/// Fixed seed for the shared LSH projection: every Bucketizer instance
/// must hash identically.
pub const PROJECTION_SEED: u64 = 0x15AB_EE75;

/// T0: text → feature vector.
pub struct TextCleaningPellet {
    pub dim: usize,
}

impl Pellet for TextCleaningPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                ctx.emit("out", m.clone());
                continue;
            }
            if let Some(t) = m.as_text() {
                let mut out = Message::f32s(featurize(t, self.dim));
                out.key = m.key.clone();
                ctx.emit("out", out);
            }
        }
        Ok(())
    }
}

/// T1/T2: LSH bucketizer over micro-batches (the flake's count window
/// delivers up to `batch` vectors per invocation).
pub struct BucketizerPellet {
    runtime: Arc<XlaRuntime>,
    model: Arc<ClusterModel>,
    projection: Arc<Vec<f32>>,
}

impl Pellet for BucketizerPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let msgs = input.messages();
        let vectors: Vec<Vec<f32>> = msgs
            .iter()
            .filter(|m| !m.is_landmark())
            .filter_map(|m| m.as_f32s().map(|v| v.to_vec()))
            .collect();
        if !vectors.is_empty() {
            let buckets = self.model.bucketize(
                &self.runtime,
                &self.projection,
                &vectors,
            )?;
            for (v, b) in vectors.into_iter().zip(buckets) {
                // Band-0 bucket id routes the post; all band ids ride
                // along in the key for candidate filtering downstream.
                let key = format!("b{}", b[0]);
                ctx.emit("out", Message::f32s(v).with_key(key));
            }
        }
        for m in msgs.iter().filter(|m| m.is_landmark()) {
            ctx.emit("out", (*m).clone());
        }
        Ok(())
    }
}

/// T3..T5: local nearest-cluster search over the shared centroids.
pub struct ClusterSearchPellet {
    runtime: Arc<XlaRuntime>,
    model: Arc<ClusterModel>,
}

impl Pellet for ClusterSearchPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        // Feedback notifications just bump a state counter (the shared
        // ClusterModel is already consistent).
        if input.port() == Some("feedback") {
            let n = input.messages().len() as f64;
            ctx.state().update_num("feedback_seen", |c| c + n);
            return Ok(());
        }
        let msgs = input.messages();
        let vectors: Vec<Vec<f32>> = msgs
            .iter()
            .filter(|m| !m.is_landmark())
            .filter_map(|m| m.as_f32s().map(|v| v.to_vec()))
            .collect();
        if !vectors.is_empty() {
            let assigns = self.model.assign(&self.runtime, &vectors)?;
            for (v, (idx, d2)) in vectors.into_iter().zip(assigns) {
                // Wire contract: [vector.., idx, d2].
                let mut payload = v;
                payload.push(idx as f32);
                payload.push(d2);
                ctx.emit("out", Message::f32s(payload));
            }
        }
        for m in msgs.iter().filter(|m| m.is_landmark()) {
            ctx.emit("out", (*m).clone());
        }
        Ok(())
    }
}

/// T6: global aggregation + streaming model update + feedback.
pub struct AggregatorPellet {
    runtime: Arc<XlaRuntime>,
    model: Arc<ClusterModel>,
}

impl Pellet for AggregatorPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let dim = self.model.params.dim;
        let mut xs = Vec::new();
        let mut assigns = Vec::new();
        for m in input.messages() {
            if m.is_landmark() {
                continue;
            }
            let Some(p) = m.as_f32s() else { continue };
            if p.len() != dim + 2 {
                continue;
            }
            let idx = p[dim] as usize;
            let d2 = p[dim + 1];
            xs.push(p[..dim].to_vec());
            assigns.push(idx);
            ctx.emit(
                "out",
                Message::text(format!("cluster={idx} d2={d2:.4}"))
                    .with_key(format!("{idx}")),
            );
        }
        if !xs.is_empty() {
            // Fold the batch into the shared model (feedback loop), then
            // notify the search pellets of the refreshed clusters.
            self.model.update(&self.runtime, &xs, &assigns)?;
            ctx.state().update_num("posts", |c| c + xs.len() as f64);
            ctx.emit("feedback", Message::text("refresh"));
        }
        Ok(())
    }
}

/// Register the `cl.*` classes bound to a runtime + shared model.
pub fn register(
    registry: &PelletRegistry,
    runtime: Arc<XlaRuntime>,
    model: Arc<ClusterModel>,
) {
    let dim = model.params.dim;
    registry.register("cl.TextCleaning", move || {
        Box::new(TextCleaningPellet { dim })
    });
    let projection = make_projection(&model.params, PROJECTION_SEED);
    let (rt, md, pj) =
        (Arc::clone(&runtime), Arc::clone(&model), Arc::clone(&projection));
    registry.register("cl.Bucketizer", move || {
        Box::new(BucketizerPellet {
            runtime: Arc::clone(&rt),
            model: Arc::clone(&md),
            projection: Arc::clone(&pj),
        })
    });
    let (rt, md) = (Arc::clone(&runtime), Arc::clone(&model));
    registry.register("cl.ClusterSearch", move || {
        Box::new(ClusterSearchPellet {
            runtime: Arc::clone(&rt),
            model: Arc::clone(&md),
        })
    });
    let (rt, md) = (Arc::clone(&runtime), Arc::clone(&model));
    registry.register("cl.Aggregator", move || {
        Box::new(AggregatorPellet {
            runtime: Arc::clone(&rt),
            model: Arc::clone(&md),
        })
    });
}

/// Build the Fig. 3b graph: `n_bucketizers` (T1/T2), `n_search`
/// ClusterSearch pellets (T3..T5), one aggregator with the feedback loop.
pub fn clustering_graph(
    batch: usize,
    n_bucketizers: usize,
    n_search: usize,
) -> Result<DataflowGraph> {
    let mut g = GraphBuilder::new("stream-clustering");
    g.pellet("clean", "cl.TextCleaning")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .cores(2)
        .latency_hint(0.001);
    for i in 0..n_bucketizers {
        g.pellet(&format!("bucketize-{i}"), "cl.Bucketizer")
            .in_port_windowed("in", WindowSpec::Count(batch))
            .out_port("out", SplitMode::KeyHash)
            .sequential() // batching via the count window; XLA is the
            .latency_hint(0.002); // data-parallel layer here
        g.edge("clean", "out", &format!("bucketize-{i}"), "in");
    }
    for j in 0..n_search {
        g.pellet(&format!("search-{j}"), "cl.ClusterSearch")
            .in_port_windowed("in", WindowSpec::Count(batch))
            .in_port("feedback")
            .out_port("out", SplitMode::RoundRobin)
            .sequential()
            .stateful()
            .latency_hint(0.002);
        for i in 0..n_bucketizers {
            g.edge(&format!("bucketize-{i}"), "out", &format!("search-{j}"), "in");
        }
    }
    g.pellet("aggregate", "cl.Aggregator")
        .in_port_windowed("in", WindowSpec::Count(batch))
        .out_port("out", SplitMode::RoundRobin)
        .out_port("feedback", SplitMode::Duplicate)
        .sequential()
        .stateful()
        .latency_hint(0.002);
    for j in 0..n_search {
        g.edge(&format!("search-{j}"), "out", "aggregate", "in");
        // Feedback loop (Fig. 3b): aggregator notifies search pellets.
        g.edge("aggregate", "feedback", &format!("search-{j}"), "feedback");
    }
    g.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_shape_matches_fig3b() {
        let g = clustering_graph(32, 2, 3).unwrap();
        // clean + 2 bucketizers + 3 search + aggregator
        assert_eq!(g.pellets.len(), 7);
        // bucketizer output is the dynamic key-hash mapping
        assert_eq!(
            g.pellet("bucketize-0")
                .unwrap()
                .out_port("out")
                .unwrap()
                .split,
            SplitMode::KeyHash
        );
        // feedback loop present: graph has back edges
        assert!(!g.back_edges().is_empty());
        // and wiring still resolves
        assert!(g.wiring_order().is_ok());
    }

    #[test]
    fn cleaning_pellet_features() {
        use crate::pellet::StateObject;
        use std::sync::atomic::AtomicBool;
        let mut p = TextCleaningPellet { dim: 64 };
        let mut c = PelletContext::new(
            "t",
            0,
            1,
            StateObject::new(),
            Arc::new(AtomicBool::new(false)),
        );
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::text("solar panels on the rooftop"),
            ),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out.len(), 1);
        let v = out[0].1.as_f32s().unwrap();
        assert_eq!(v.len(), 64);
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }
}
