//! Shared cluster model and typed wrappers over the AOT XLA kernels
//! (`bucketize`, `cluster_assign`, `centroid_update`) for the
//! stream-clustering pellets.

use std::sync::{Arc, Mutex};

use crate::error::{FloeError, Result};
use crate::runtime::{Manifest, Tensor, XlaRuntime};
use crate::util::rng::Rng;

/// Static shape parameters shared with `python/compile/model.py` through
/// `artifacts/manifest.json`.
#[derive(Debug, Clone, Copy)]
pub struct ClusterParams {
    pub batch: usize,
    pub dim: usize,
    pub n_bands: usize,
    pub band_width: usize,
    pub n_clusters: usize,
}

impl ClusterParams {
    pub fn from_manifest(m: &Manifest) -> Result<ClusterParams> {
        Ok(ClusterParams {
            batch: m.config_usize("batch")?,
            dim: m.config_usize("dim")?,
            n_bands: m.config_usize("n_bands")?,
            band_width: m.config_usize("band_width")?,
            n_clusters: m.config_usize("n_clusters")?,
        })
    }
}

/// Random LSH projection matrix `[dim, n_bands × band_width]`, seeded so
/// every bucketizer pellet instance agrees.
pub fn make_projection(p: &ClusterParams, seed: u64) -> Arc<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let n = p.dim * p.n_bands * p.band_width;
    Arc::new((0..n).map(|_| rng.normal() as f32).collect())
}

/// The shared, continuously updated cluster state (centroids + counts).
pub struct ClusterModel {
    pub params: ClusterParams,
    inner: Mutex<ModelState>,
}

struct ModelState {
    /// `[n_clusters × dim]`, row-major.
    centroids: Vec<f32>,
    /// `[n_clusters]` assigned-post counts.
    counts: Vec<f32>,
    updates: u64,
}

impl ClusterModel {
    /// Random unit-vector centroids.
    pub fn new_random(params: ClusterParams, seed: u64) -> Arc<ClusterModel> {
        let mut rng = Rng::new(seed);
        let mut centroids = vec![0f32; params.n_clusters * params.dim];
        for row in centroids.chunks_mut(params.dim) {
            let mut norm = 0f32;
            for x in row.iter_mut() {
                *x = rng.normal() as f32;
                norm += *x * *x;
            }
            let norm = norm.sqrt().max(1e-6);
            for x in row.iter_mut() {
                *x /= norm;
            }
        }
        Arc::new(ClusterModel {
            params,
            inner: Mutex::new(ModelState {
                centroids,
                counts: vec![0f32; params.n_clusters],
                updates: 0,
            }),
        })
    }

    pub fn centroids_snapshot(&self) -> (Vec<f32>, Vec<f32>) {
        let g = self.inner.lock().expect("model poisoned");
        (g.centroids.clone(), g.counts.clone())
    }

    pub fn update_count(&self) -> u64 {
        self.inner.lock().expect("model poisoned").updates
    }

    /// Pad a partial batch of `dim`-length vectors to the static batch
    /// shape; returns (flat x, valid count).
    fn pad_batch(&self, xs: &[Vec<f32>]) -> Result<(Vec<f32>, usize)> {
        let p = &self.params;
        if xs.len() > p.batch {
            return Err(FloeError::Runtime(format!(
                "batch {} exceeds static batch {}",
                xs.len(),
                p.batch
            )));
        }
        let mut flat = vec![0f32; p.batch * p.dim];
        for (i, x) in xs.iter().enumerate() {
            if x.len() != p.dim {
                return Err(FloeError::Runtime(format!(
                    "vector {i} has dim {}, expected {}",
                    x.len(),
                    p.dim
                )));
            }
            flat[i * p.dim..(i + 1) * p.dim].copy_from_slice(x);
        }
        Ok((flat, xs.len()))
    }

    /// LSH bucket ids per band for each vector (bucketize kernel).
    pub fn bucketize(
        &self,
        rt: &XlaRuntime,
        proj: &[f32],
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<i32>>> {
        let p = &self.params;
        let (flat, n) = self.pad_batch(xs)?;
        let lk = p.n_bands * p.band_width;
        let out = rt.execute("bucketize", &[
            Tensor::f32(&[p.batch, p.dim], flat),
            Tensor::f32(&[p.dim, lk], proj.to_vec()),
        ])?;
        let ids = out[0].as_i32().ok_or_else(|| {
            FloeError::Runtime("bucketize: expected i32 output".into())
        })?;
        Ok((0..n)
            .map(|i| ids[i * p.n_bands..(i + 1) * p.n_bands].to_vec())
            .collect())
    }

    /// Nearest-centroid assignment (cluster_assign kernel).  Returns
    /// `(cluster idx, squared distance)` per input vector.
    pub fn assign(
        &self,
        rt: &XlaRuntime,
        xs: &[Vec<f32>],
    ) -> Result<Vec<(usize, f32)>> {
        let p = &self.params;
        let (flat, n) = self.pad_batch(xs)?;
        let (centroids, _) = self.centroids_snapshot();
        let mask = vec![1f32; p.batch * p.n_clusters];
        let out = rt.execute("cluster_assign", &[
            Tensor::f32(&[p.batch, p.dim], flat),
            Tensor::f32(&[p.n_clusters, p.dim], centroids),
            Tensor::f32(&[p.batch, p.n_clusters], mask),
        ])?;
        let idx = out[0].as_i32().ok_or_else(|| {
            FloeError::Runtime("cluster_assign: expected i32".into())
        })?;
        let dist = out[1].as_f32().ok_or_else(|| {
            FloeError::Runtime("cluster_assign: expected f32".into())
        })?;
        Ok((0..n).map(|i| (idx[i] as usize, dist[i])).collect())
    }

    /// Streaming centroid update (centroid_update kernel) — the feedback
    /// loop that folds newly assigned posts into the shared model.
    pub fn update(
        &self,
        rt: &XlaRuntime,
        xs: &[Vec<f32>],
        assigns: &[usize],
    ) -> Result<()> {
        if xs.len() != assigns.len() {
            return Err(FloeError::Runtime(
                "update: xs/assigns length mismatch".into(),
            ));
        }
        let p = &self.params;
        let (flat, n) = self.pad_batch(xs)?;
        let mut idx = vec![0i32; p.batch];
        let mut valid = vec![0f32; p.batch];
        for i in 0..n {
            idx[i] = assigns[i] as i32;
            valid[i] = 1.0;
        }
        let mut g = self.inner.lock().expect("model poisoned");
        let out = rt.execute("centroid_update", &[
            Tensor::f32(&[p.batch, p.dim], flat),
            Tensor::f32(&[p.n_clusters, p.dim], g.centroids.clone()),
            Tensor::f32(&[p.n_clusters], g.counts.clone()),
            Tensor::i32(&[p.batch], idx),
            Tensor::f32(&[p.batch], valid),
        ])?;
        g.centroids = out[0]
            .as_f32()
            .ok_or_else(|| {
                FloeError::Runtime("centroid_update: expected f32".into())
            })?
            .to_vec();
        g.counts = out[1]
            .as_f32()
            .ok_or_else(|| {
                FloeError::Runtime("centroid_update: expected f32".into())
            })?
            .to_vec();
        g.updates += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ClusterParams {
        ClusterParams {
            batch: 32,
            dim: 64,
            n_bands: 8,
            band_width: 12,
            n_clusters: 16,
        }
    }

    #[test]
    fn projection_is_seeded() {
        let p = params();
        let a = make_projection(&p, 7);
        let b = make_projection(&p, 7);
        assert_eq!(a.len(), 64 * 8 * 12);
        assert_eq!(*a, *b);
        let c = make_projection(&p, 8);
        assert_ne!(*a, *c);
    }

    #[test]
    fn centroids_are_unit_norm() {
        let m = ClusterModel::new_random(params(), 3);
        let (c, counts) = m.centroids_snapshot();
        assert_eq!(c.len(), 16 * 64);
        assert!(counts.iter().all(|&x| x == 0.0));
        for row in c.chunks(64) {
            let n: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn pad_batch_validates() {
        let m = ClusterModel::new_random(params(), 3);
        let ok = m.pad_batch(&vec![vec![0.0; 64]; 5]).unwrap();
        assert_eq!(ok.0.len(), 32 * 64);
        assert_eq!(ok.1, 5);
        assert!(m.pad_batch(&[vec![0.0; 63]]).is_err());
        assert!(m.pad_batch(&vec![vec![0.0; 64]; 40]).is_err());
    }
}
