//! Text cleaning (Fig. 3b, T0): stop-word removal, light suffix stemming
//! and dictionary-hash feature vectors — plus the synthetic topic-mixture
//! post generator standing in for the paper's news/microblog feeds.

use crate::message::key_hash;
use crate::util::rng::Rng;

/// Common English stop words (enough for the synthetic corpus).
const STOPWORDS: &[&str] = &[
    "a", "an", "the", "is", "are", "was", "were", "be", "been", "to", "of",
    "and", "or", "in", "on", "at", "for", "with", "it", "this", "that",
    "from", "by", "as", "but", "not", "they", "we", "you", "i", "he",
    "she", "its", "their", "our", "your", "my", "so", "do", "does", "did",
];

/// Light suffix stemmer (Porter-inspired, first pass only).
pub fn stem(word: &str) -> String {
    let w = word.to_lowercase();
    for suffix in ["ments", "ment", "ings", "ing", "edly", "ed", "ies", "es", "s"]
    {
        if let Some(base) = w.strip_suffix(suffix) {
            if base.len() >= 3 {
                return base.to_string();
            }
        }
    }
    w
}

/// Tokenize, drop stop words and punctuation, stem.
pub fn clean_tokens(text: &str) -> Vec<String> {
    text.split(|c: char| !c.is_alphanumeric())
        .filter(|t| t.len() > 1)
        .map(|t| t.to_lowercase())
        .filter(|t| !STOPWORDS.contains(&t.as_str()))
        .map(|t| stem(&t))
        .collect()
}

/// Dictionary-hash featurizer: token counts hashed into `dim` buckets,
/// L2-normalized — "a feature vector based on dictionary of topic words"
/// (§IV-B).  Normalization makes the LSH sign-projection scale-invariant.
pub fn featurize(text: &str, dim: usize) -> Vec<f32> {
    let mut v = vec![0f32; dim];
    for tok in clean_tokens(text) {
        let idx = (key_hash(&tok) % dim as u64) as usize;
        v[idx] += 1.0;
    }
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    v
}

/// Topic vocabularies for the synthetic post stream.
const TOPICS: &[&[&str]] = &[
    &["energy", "power", "grid", "meter", "kilowatt", "voltage", "demand",
      "outage", "transformer", "utility"],
    &["solar", "panel", "rooftop", "inverter", "sunlight", "renewable",
      "battery", "storage", "charge", "cell"],
    &["price", "market", "tariff", "billing", "cost", "saving", "rebate",
      "discount", "payment", "budget"],
    &["weather", "storm", "heat", "temperature", "forecast", "wind",
      "humidity", "rain", "cloud", "front"],
    &["campus", "building", "classroom", "laboratory", "dorm", "office",
      "facility", "renovation", "hvac", "lighting"],
    &["football", "game", "score", "team", "season", "coach", "stadium",
      "playoff", "touchdown", "fans"],
    &["movie", "film", "actor", "premiere", "trailer", "studio", "scene",
      "director", "cinema", "award"],
    &["traffic", "freeway", "commute", "accident", "lane", "downtown",
      "transit", "parking", "detour", "rush"],
];

/// Number of distinct topics in the generator.
pub fn topic_count() -> usize {
    TOPICS.len()
}

/// Synthetic microblog post generator: each post mixes words from one
/// dominant topic with a little noise from others.
pub struct PostGen {
    rng: Rng,
}

impl PostGen {
    pub fn new(seed: u64) -> PostGen {
        PostGen { rng: Rng::new(seed) }
    }

    /// Generate `(topic id, post text)`.
    pub fn post(&mut self) -> (usize, String) {
        let topic = self.rng.range(0, TOPICS.len());
        let words = 6 + self.rng.range(0, 8);
        let mut out = Vec::with_capacity(words);
        for _ in 0..words {
            let from = if self.rng.chance(0.85) {
                TOPICS[topic]
            } else {
                TOPICS[self.rng.range(0, TOPICS.len())]
            };
            out.push(*self.rng.pick(from));
            if self.rng.chance(0.3) {
                out.push(*self.rng.pick(STOPWORDS));
            }
        }
        (topic, out.join(" "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stemming_examples() {
        assert_eq!(stem("charging"), "charg");
        assert_eq!(stem("batteries"), "batter");
        assert_eq!(stem("meters"), "meter");
        assert_eq!(stem("payment"), "pay");
        assert_eq!(stem("grid"), "grid");
        // too-short bases keep the suffix
        assert_eq!(stem("es"), "es");
    }

    #[test]
    fn clean_drops_stopwords_and_punct() {
        let toks = clean_tokens("The grid is down, and the METERS are out!");
        assert!(toks.contains(&"grid".to_string()));
        assert!(toks.contains(&"meter".to_string()));
        assert!(!toks.iter().any(|t| t == "the" || t == "is" || t == "and"));
    }

    #[test]
    fn featurize_normalized_and_scale_invariant() {
        let v = featurize("solar panel rooftop solar", 64);
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        // Same tokens -> same vector.
        let w = featurize("solar panel rooftop solar", 64);
        assert_eq!(v, w);
        // Empty text -> zero vector, no NaN.
        let z = featurize("", 64);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn same_topic_posts_are_closer() {
        let mut g = PostGen::new(42);
        // Collect a few posts per topic.
        let mut by_topic: Vec<Vec<Vec<f32>>> = vec![vec![]; topic_count()];
        for _ in 0..400 {
            let (t, text) = g.post();
            by_topic[t].push(featurize(&text, 64));
        }
        let dot = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        };
        // Average intra-topic similarity should beat inter-topic.
        let t0 = &by_topic[0];
        let t5 = &by_topic[5];
        assert!(t0.len() > 5 && t5.len() > 5);
        let intra: f32 = dot(&t0[0], &t0[1]);
        let inter: f32 = dot(&t0[0], &t5[0]);
        assert!(
            intra > inter,
            "intra {intra} should exceed inter {inter}"
        );
    }
}
