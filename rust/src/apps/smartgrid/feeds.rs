//! Synthetic data feeds standing in for the USC campus microgrid sources
//! (§IV-A): smart-meter events, building sensor streams, bulk CSV meter
//! archives, and NOAA-style XML weather observations.
//!
//! Formats:
//! * meter/sensor event — `meter,<building>,<ts>,<kwh>` /
//!   `sensor,<building>,<ts>,<temp_f>`
//! * CSV archive row — `<building>,<ts>,<kwh>` (header skipped)
//! * NOAA XML — `<current_observation><station>..</station>
//!   <temp_f>..</temp_f><wind_mph>..</wind_mph></current_observation>`

use crate::util::rng::Rng;

/// Generator for campus meter/sensor events.
pub struct FeedGen {
    rng: Rng,
    buildings: usize,
    ts: u64,
}

impl FeedGen {
    pub fn new(seed: u64, buildings: usize) -> FeedGen {
        FeedGen { rng: Rng::new(seed), buildings: buildings.max(1), ts: 0 }
    }

    /// One smart-meter event line.
    pub fn meter_event(&mut self) -> String {
        self.ts += 1;
        let b = self.rng.range(0, self.buildings);
        let kwh = 2.0 + 3.0 * self.rng.f64();
        format!("meter,bldg{b},{},{kwh:.3}", self.ts)
    }

    /// One building-sensor event line.
    pub fn sensor_event(&mut self) -> String {
        self.ts += 1;
        let b = self.rng.range(0, self.buildings);
        let temp = 60.0 + 25.0 * self.rng.f64();
        format!("sensor,bldg{b},{},{temp:.1}", self.ts)
    }

    /// A bulk CSV archive with `rows` historical meter readings.
    pub fn csv_archive(&mut self, rows: usize) -> String {
        let mut out = String::from("building,ts,kwh\n");
        for _ in 0..rows {
            self.ts += 1;
            let b = self.rng.range(0, self.buildings);
            let kwh = 1.0 + 4.0 * self.rng.f64();
            out.push_str(&format!("bldg{b},{},{kwh:.3}\n", self.ts));
        }
        out
    }

    /// A NOAA-style current-observation XML document.
    pub fn noaa_xml(&mut self) -> String {
        self.ts += 1;
        let temp = 55.0 + 30.0 * self.rng.f64();
        let wind = 10.0 * self.rng.f64();
        let station = ["KLAX", "KBUR", "KSMO"][self.rng.range(0, 3)];
        format!(
            "<current_observation><station>{station}</station>\
             <observation_ts>{}</observation_ts>\
             <temp_f>{temp:.1}</temp_f><wind_mph>{wind:.1}</wind_mph>\
             </current_observation>",
            self.ts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::xml::XmlNode;

    #[test]
    fn meter_events_parse() {
        let mut g = FeedGen::new(1, 10);
        for _ in 0..20 {
            let e = g.meter_event();
            let parts: Vec<&str> = e.split(',').collect();
            assert_eq!(parts.len(), 4);
            assert_eq!(parts[0], "meter");
            assert!(parts[1].starts_with("bldg"));
            assert!(parts[3].parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn csv_archive_rows() {
        let mut g = FeedGen::new(2, 5);
        let csv = g.csv_archive(50);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 51); // header + 50
        assert_eq!(lines[0], "building,ts,kwh");
    }

    #[test]
    fn noaa_xml_is_valid() {
        let mut g = FeedGen::new(3, 5);
        let doc = g.noaa_xml();
        let node = XmlNode::parse(&doc).unwrap();
        assert_eq!(node.name, "current_observation");
        let t: f64 =
            node.child("temp_f").unwrap().text.parse().unwrap();
        assert!((55.0..=85.0).contains(&t));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = FeedGen::new(7, 4);
        let mut b = FeedGen::new(7, 4);
        assert_eq!(a.meter_event(), b.meter_event());
        assert_eq!(a.noaa_xml(), b.noaa_xml());
    }
}
