//! Smart Grid information-integration pipeline (Fig. 3a).
//!
//! Pipeline pellets (`sg.*` classes):
//!
//! ```text
//! I0 meter events  ┐
//! I1 sensor stream ┤(interleave)→ I2 sg.Parse → I3 sg.Annotate ─┬→ I4 sg.InsertMeter   → I5 sg.Progress
//! I6 CSV archive   ┤                              (switch)      ├→ I8 sg.InsertWeather → I5
//! I7 NOAA XML      ┘                                            └→ I9 sg.InsertBulk    → I5
//! ```
//!
//! `sg.Parse` normalizes the four source formats into
//! `kind|building|ts|value` records (selectivity > 1 for CSV archives:
//! one row per record).  `sg.Annotate` adds semantic context and routes by
//! kind on separate output ports — the paper's switch control-flow
//! pattern.  The insert pellets write triples into the shared
//! [`TripleStore`] (the 4Store substitute) and report to `sg.Progress`.

mod feeds;
mod store;

pub use feeds::FeedGen;
pub use store::{Triple, TripleStore};

use std::sync::Arc;

use crate::error::Result;
use crate::graph::{DataflowGraph, GraphBuilder, MergeMode, SplitMode};
use crate::message::Message;
use crate::pellet::{Pellet, PelletContext, PelletRegistry, PortIo};
use crate::util::xml::XmlNode;

/// Parse pellet (I2): normalize raw source payloads into
/// `kind|building|ts|value` records.
pub struct ParsePellet;

impl ParsePellet {
    fn parse_text(&self, text: &str, ctx: &mut PelletContext) {
        if text.starts_with('<') {
            // NOAA XML document.
            if let Ok(node) = XmlNode::parse(text) {
                let station = node
                    .child("station")
                    .map(|c| c.text.clone())
                    .unwrap_or_else(|| "unknown".into());
                let ts = node
                    .child("observation_ts")
                    .map(|c| c.text.clone())
                    .unwrap_or_default();
                if let Some(temp) = node.child("temp_f") {
                    ctx.emit(
                        "out",
                        Message::text(format!(
                            "weather|{station}|{ts}|{}",
                            temp.text
                        )),
                    );
                }
            } else {
                ctx.emit("err", Message::text(text.to_string()));
            }
        } else if text.contains('\n') {
            // Bulk CSV archive: one record per data row.
            for line in text.lines().skip(1) {
                let f = crate::util::csv::parse_line(line);
                if f.len() == 3 {
                    ctx.emit(
                        "out",
                        Message::text(format!(
                            "bulk|{}|{}|{}",
                            f[0], f[1], f[2]
                        )),
                    );
                }
            }
        } else {
            // Single meter/sensor event.
            let f: Vec<&str> = text.split(',').collect();
            if f.len() == 4 && (f[0] == "meter" || f[0] == "sensor") {
                ctx.emit(
                    "out",
                    Message::text(format!(
                        "{}|{}|{}|{}",
                        f[0], f[1], f[2], f[3]
                    )),
                );
            } else {
                ctx.emit("err", Message::text(text.to_string()));
            }
        }
    }
}

impl Pellet for ParsePellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                ctx.emit("out", m.clone());
                continue;
            }
            if let Some(t) = m.as_text() {
                self.parse_text(t, ctx);
            }
        }
        Ok(())
    }
}

/// Annotate pellet (I3): attach semantic context and switch on record kind
/// (Fig. 1 control-flow pattern): meter/sensor → `meter` port, weather →
/// `weather` port, bulk archives → `bulk` port.
pub struct AnnotatePellet;

impl Pellet for AnnotatePellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                for port in ["meter", "weather", "bulk"] {
                    ctx.emit(port, m.clone());
                }
                continue;
            }
            let Some(t) = m.as_text() else { continue };
            let f: Vec<&str> = t.split('|').collect();
            if f.len() != 4 {
                continue;
            }
            let (kind, entity, ts, value) = (f[0], f[1], f[2], f[3]);
            // Semantic annotation: subject URI + typed predicate.
            let subject = format!("usc:{entity}");
            let (port, predicate) = match kind {
                "meter" => ("meter", "grid:kwh"),
                "sensor" => ("meter", "grid:temp_f"),
                "weather" => ("weather", "noaa:temp_f"),
                "bulk" => ("bulk", "grid:kwh_hist"),
                _ => continue,
            };
            ctx.emit(
                port,
                Message::text(format!("{subject}|{predicate}|{value}|{ts}"))
                    .with_key(subject.clone()),
            );
        }
        Ok(())
    }
}

/// Insert pellet (I4/I8/I9): write annotated triples into the shared
/// store, then report progress.
pub struct InsertPellet {
    store: Arc<TripleStore>,
    /// Upsert (live readings) or append (historical bulk).
    upsert: bool,
}

impl Pellet for InsertPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                ctx.emit("out", m.clone());
                continue;
            }
            let Some(t) = m.as_text() else { continue };
            let f: Vec<&str> = t.split('|').collect();
            if f.len() != 4 {
                continue;
            }
            let triple = Triple::new(f[0], f[1], f[2]);
            if self.upsert {
                self.store.upsert(triple);
            } else {
                self.store.insert(triple);
            }
            ctx.emit("out", Message::text(format!("ok|{}", f[0])));
        }
        Ok(())
    }
}

/// Progress pellet (I5): counts successful ingests in its state object.
pub struct ProgressPellet;

impl Pellet for ProgressPellet {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let n = input
            .messages()
            .iter()
            .filter(|m| !m.is_landmark())
            .count() as f64;
        ctx.state().update_num("ingested", |c| c + n);
        Ok(())
    }
}

/// Register the `sg.*` pellet classes against a shared store.
pub fn register(registry: &PelletRegistry, store: Arc<TripleStore>) {
    registry.register("sg.Parse", || Box::new(ParsePellet));
    registry.register("sg.Annotate", || Box::new(AnnotatePellet));
    let s = Arc::clone(&store);
    registry.register("sg.InsertMeter", move || {
        Box::new(InsertPellet { store: Arc::clone(&s), upsert: true })
    });
    let s = Arc::clone(&store);
    registry.register("sg.InsertWeather", move || {
        Box::new(InsertPellet { store: Arc::clone(&s), upsert: true })
    });
    let s = Arc::clone(&store);
    registry.register("sg.InsertBulk", move || {
        Box::new(InsertPellet { store: Arc::clone(&s), upsert: false })
    });
    registry.register("sg.Progress", || Box::new(ProgressPellet));
}

/// Build the Fig. 3a graph.  Latency/selectivity hints mirror the figure's
/// per-pellet annotations and feed the static look-ahead strategy.
pub fn integration_graph() -> Result<DataflowGraph> {
    let mut g = GraphBuilder::new("smartgrid-integration");
    g.pellet("parse", "sg.Parse")
        .in_port("in") // interleaved merge of all four sources (I0/I1/I6/I7)
        .out_port("out", SplitMode::RoundRobin)
        .out_port("err", SplitMode::RoundRobin)
        .latency_hint(0.002)
        .selectivity_hint(1.0)
        .merge(MergeMode::Interleaved);
    g.pellet("annotate", "sg.Annotate")
        .in_port("in")
        .out_port("meter", SplitMode::RoundRobin)
        .out_port("weather", SplitMode::RoundRobin)
        .out_port("bulk", SplitMode::RoundRobin)
        .latency_hint(0.005)
        .selectivity_hint(1.0);
    g.pellet("insert-meter", "sg.InsertMeter")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .cores(2)
        .latency_hint(0.010);
    g.pellet("insert-weather", "sg.InsertWeather")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .latency_hint(0.010);
    g.pellet("insert-bulk", "sg.InsertBulk")
        .in_port("in")
        .out_port("out", SplitMode::RoundRobin)
        .latency_hint(0.010);
    g.pellet("progress", "sg.Progress").in_port("in").stateful();
    g.edge("parse", "out", "annotate", "in");
    g.edge("annotate", "meter", "insert-meter", "in");
    g.edge("annotate", "weather", "insert-weather", "in");
    g.edge("annotate", "bulk", "insert-bulk", "in");
    g.edge("insert-meter", "out", "progress", "in");
    g.edge("insert-weather", "out", "progress", "in");
    g.edge("insert-bulk", "out", "progress", "in");
    g.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pellet::StateObject;
    use std::sync::atomic::AtomicBool;

    fn ctx() -> PelletContext {
        PelletContext::new(
            "t",
            0,
            1,
            StateObject::new(),
            Arc::new(AtomicBool::new(false)),
        )
    }

    #[test]
    fn parse_meter_event() {
        let mut p = ParsePellet;
        let mut c = ctx();
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::text("meter,bldg3,100,4.25"),
            ),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.as_text(), Some("meter|bldg3|100|4.25"));
    }

    #[test]
    fn parse_noaa_xml() {
        let mut p = ParsePellet;
        let mut c = ctx();
        let mut gen = FeedGen::new(1, 4);
        p.compute(
            PortIo::Single("in".into(), Message::text(gen.noaa_xml())),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out.len(), 1);
        assert!(out[0].1.as_text().unwrap().starts_with("weather|K"));
    }

    #[test]
    fn parse_csv_expands_rows() {
        let mut p = ParsePellet;
        let mut c = ctx();
        let mut gen = FeedGen::new(2, 4);
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::text(gen.csv_archive(25)),
            ),
            &mut c,
        )
        .unwrap();
        // selectivity 25: one record per row
        assert_eq!(c.take_emitted().len(), 25);
    }

    #[test]
    fn parse_garbage_to_err_port() {
        let mut p = ParsePellet;
        let mut c = ctx();
        p.compute(
            PortIo::Single("in".into(), Message::text("what,is,this")),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out[0].0, "err");
    }

    #[test]
    fn annotate_switches_by_kind() {
        let mut a = AnnotatePellet;
        let mut c = ctx();
        for (rec, want_port) in [
            ("meter|bldg1|5|3.2", "meter"),
            ("sensor|bldg1|6|70.1", "meter"),
            ("weather|KLAX|7|68.0", "weather"),
            ("bulk|bldg2|8|2.2", "bulk"),
        ] {
            a.compute(
                PortIo::Single("in".into(), Message::text(rec)),
                &mut c,
            )
            .unwrap();
            let out = c.take_emitted();
            assert_eq!(out.len(), 1, "{rec}");
            assert_eq!(out[0].0, want_port, "{rec}");
            assert!(out[0].1.as_text().unwrap().starts_with("usc:"));
        }
    }

    #[test]
    fn insert_writes_store_and_reports() {
        let store = Arc::new(TripleStore::new());
        let mut p =
            InsertPellet { store: Arc::clone(&store), upsert: true };
        let mut c = ctx();
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::text("usc:bldg1|grid:kwh|4.2|100"),
            ),
            &mut c,
        )
        .unwrap();
        // Upsert replaces on second write.
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::text("usc:bldg1|grid:kwh|5.0|101"),
            ),
            &mut c,
        )
        .unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(
            store.query(Some("usc:bldg1"), None, None)[0].object,
            "5.0"
        );
        assert_eq!(c.take_emitted().len(), 2);
    }

    #[test]
    fn graph_validates_and_orders() {
        let g = integration_graph().unwrap();
        assert_eq!(g.pellets.len(), 6);
        let order = g.wiring_order().unwrap();
        let pos =
            |id: &str| order.iter().position(|x| x == id).unwrap();
        assert!(pos("progress") < pos("insert-meter"));
        assert!(pos("insert-meter") < pos("annotate"));
        assert!(pos("annotate") < pos("parse"));
    }
}
