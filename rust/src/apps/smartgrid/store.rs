//! In-memory semantic triple store — the offline substitute for the 4Store
//! database the paper's pipeline inserts into (I4, I8, I9).  Supports
//! insert, upsert-by-subject-predicate and wildcard pattern queries, which
//! is the full surface the pipeline pellets need.

use std::collections::HashMap;
use std::sync::Mutex;

/// An RDF-ish triple.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Triple {
    pub subject: String,
    pub predicate: String,
    pub object: String,
}

impl Triple {
    pub fn new(
        s: impl Into<String>,
        p: impl Into<String>,
        o: impl Into<String>,
    ) -> Triple {
        Triple { subject: s.into(), predicate: p.into(), object: o.into() }
    }
}

/// Thread-safe triple store with subject indexing.
pub struct TripleStore {
    inner: Mutex<Store>,
}

struct Store {
    triples: Vec<Triple>,
    /// subject -> indices (accelerates the pipeline's upsert path).
    by_subject: HashMap<String, Vec<usize>>,
}

impl TripleStore {
    pub fn new() -> TripleStore {
        TripleStore {
            inner: Mutex::new(Store {
                triples: Vec::new(),
                by_subject: HashMap::new(),
            }),
        }
    }

    /// Append a triple.
    pub fn insert(&self, t: Triple) {
        let mut g = self.inner.lock().expect("store poisoned");
        let idx = g.triples.len();
        g.by_subject
            .entry(t.subject.clone())
            .or_default()
            .push(idx);
        g.triples.push(t);
    }

    /// Replace the object of an existing (subject, predicate) pair or
    /// insert — the "insert/update these semantic triples" path (§IV-A).
    pub fn upsert(&self, t: Triple) {
        let mut g = self.inner.lock().expect("store poisoned");
        if let Some(indices) = g.by_subject.get(&t.subject) {
            for &i in indices {
                if g.triples[i].predicate == t.predicate {
                    g.triples[i].object = t.object;
                    return;
                }
            }
        }
        let idx = g.triples.len();
        g.by_subject
            .entry(t.subject.clone())
            .or_default()
            .push(idx);
        g.triples.push(t);
    }

    /// Wildcard query: None matches anything.
    pub fn query(
        &self,
        s: Option<&str>,
        p: Option<&str>,
        o: Option<&str>,
    ) -> Vec<Triple> {
        let g = self.inner.lock().expect("store poisoned");
        // Use the subject index when possible.
        let candidates: Vec<&Triple> = match s {
            Some(subj) => g
                .by_subject
                .get(subj)
                .map(|idx| idx.iter().map(|&i| &g.triples[i]).collect())
                .unwrap_or_default(),
            None => g.triples.iter().collect(),
        };
        candidates
            .into_iter()
            .filter(|t| p.map(|p| t.predicate == p).unwrap_or(true))
            .filter(|t| o.map(|o| t.object == o).unwrap_or(true))
            .cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("store poisoned").triples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for TripleStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_query() {
        let s = TripleStore::new();
        s.insert(Triple::new("bldg:12", "grid:kwh", "4.2"));
        s.insert(Triple::new("bldg:12", "grid:temp", "71"));
        s.insert(Triple::new("bldg:13", "grid:kwh", "3.0"));
        assert_eq!(s.len(), 3);
        assert_eq!(s.query(Some("bldg:12"), None, None).len(), 2);
        assert_eq!(s.query(None, Some("grid:kwh"), None).len(), 2);
        assert_eq!(
            s.query(Some("bldg:13"), Some("grid:kwh"), None)[0].object,
            "3.0"
        );
        assert_eq!(s.query(None, None, Some("71")).len(), 1);
        assert!(s.query(Some("nope"), None, None).is_empty());
    }

    #[test]
    fn upsert_replaces_object() {
        let s = TripleStore::new();
        s.upsert(Triple::new("bldg:1", "grid:kwh", "1.0"));
        s.upsert(Triple::new("bldg:1", "grid:kwh", "2.0"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.query(Some("bldg:1"), None, None)[0].object, "2.0");
        s.upsert(Triple::new("bldg:1", "grid:temp", "70"));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn concurrent_inserts() {
        use std::sync::Arc;
        let s = Arc::new(TripleStore::new());
        let handles: Vec<_> = (0..4)
            .map(|k| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        s.insert(Triple::new(
                            format!("s{k}-{i}"),
                            "p",
                            "o",
                        ));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 400);
    }
}
