//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the Floe framework.
#[derive(Error, Debug)]
pub enum FloeError {
    /// Dataflow graph is malformed (unknown pellet, dangling port, ...).
    #[error("graph error: {0}")]
    Graph(String),

    /// A pellet failed during setup, compute or teardown.
    #[error("pellet error: {0}")]
    Pellet(String),

    /// A data channel failed (peer gone, framing error, backpressure abort).
    #[error("channel error: {0}")]
    Channel(String),

    /// Resource allocation failed (no cores, no VMs, bad request).
    #[error("resource error: {0}")]
    Resource(String),

    /// XLA/PJRT runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Text parsing failure (JSON, XML, CSV, HTTP, graph files).
    #[error("parse error: {0}")]
    Parse(String),

    /// Control-plane failure (REST endpoint, coordinator RPC).
    #[error("control error: {0}")]
    Control(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for FloeError {
    fn from(e: xla::Error) -> Self {
        FloeError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, FloeError>;
