//! Crate-wide error type (std-only; no external error-derive crates in
//! the offline build environment).

use std::fmt;

/// Errors surfaced by the Floe framework.
#[derive(Debug)]
pub enum FloeError {
    /// Dataflow graph is malformed (unknown pellet, dangling port, ...).
    Graph(String),

    /// A pellet failed during setup, compute or teardown.
    Pellet(String),

    /// A data channel failed (peer gone, framing error, backpressure
    /// abort).
    Channel(String),

    /// Resource allocation failed (no cores, no VMs, bad request).
    Resource(String),

    /// Live recomposition failed (unsupported surgery against the
    /// running topology).
    Recompose(String),

    /// XLA/PJRT runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Text parsing failure (JSON, XML, CSV, HTTP, graph files).
    Parse(String),

    /// Control-plane failure (REST endpoint, coordinator RPC).
    Control(String),

    /// I/O failure (sockets, files).
    Io(std::io::Error),
}

impl fmt::Display for FloeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FloeError::Graph(m) => write!(f, "graph error: {m}"),
            FloeError::Pellet(m) => write!(f, "pellet error: {m}"),
            FloeError::Channel(m) => write!(f, "channel error: {m}"),
            FloeError::Resource(m) => write!(f, "resource error: {m}"),
            FloeError::Recompose(m) => write!(f, "recompose error: {m}"),
            FloeError::Runtime(m) => write!(f, "runtime error: {m}"),
            FloeError::Parse(m) => write!(f, "parse error: {m}"),
            FloeError::Control(m) => write!(f, "control error: {m}"),
            FloeError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for FloeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FloeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FloeError {
    fn from(e: std::io::Error) -> Self {
        FloeError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for FloeError {
    fn from(e: xla::Error) -> Self {
        FloeError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, FloeError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        assert_eq!(
            FloeError::Graph("bad edge".into()).to_string(),
            "graph error: bad edge"
        );
        assert_eq!(
            FloeError::Channel("closed".into()).to_string(),
            "channel error: closed"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::other("boom");
        let e: FloeError = io.into();
        assert!(e.to_string().contains("boom"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
