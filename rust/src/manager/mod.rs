//! Resource manager (§III): acquires and releases VMs from a cloud
//! provider on demand and hands containers to the coordinator using a
//! best-fit packing policy.
//!
//! The paper ran on a Eucalyptus private cloud; offline we substitute
//! [`SimulatedCloud`] — same acquire/release surface, configurable node
//! inventory (default: the paper's Tsangpo cloud, 16 nodes × 8 cores) and
//! provisioning delay, so every coordinator/adaptation decision path is
//! exercised identically (see DESIGN.md §Environment-substitutions).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::container::Container;
use crate::error::{FloeError, Result};
use crate::util::json::Json;

/// VM classes mirroring the paper's Eucalyptus instance types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VmClass {
    /// 2 cores, paper's small instance.
    Small,
    /// 4 cores.
    Large,
    /// 8 cores, 16 GB — the paper's Extra Large used for the pipeline.
    ExtraLarge,
}

impl VmClass {
    pub fn cores(&self) -> usize {
        match self {
            VmClass::Small => 2,
            VmClass::Large => 4,
            VmClass::ExtraLarge => 8,
        }
    }
}

/// A granted VM.
#[derive(Debug, Clone)]
pub struct VmHandle {
    pub id: String,
    pub class: VmClass,
}

/// Cloud fabric abstraction (Eucalyptus/AWS in the paper).
pub trait CloudProvider: Send + Sync {
    /// Acquire a VM of the class, blocking for the provisioning delay.
    fn acquire_vm(&self, class: VmClass) -> Result<VmHandle>;

    /// Release a VM back to the fabric.
    fn release_vm(&self, id: &str) -> Result<()>;

    /// VMs currently provisioned.
    fn active_vms(&self) -> usize;

    /// Total cores in the fabric.
    fn capacity_cores(&self) -> usize;
}

/// Simulated private cloud: fixed node inventory, optional provisioning
/// delay, acquisition failure when capacity is exhausted.
pub struct SimulatedCloud {
    total_cores: usize,
    used_cores: Mutex<HashMap<String, usize>>,
    provisioning_delay: Duration,
    next_id: AtomicUsize,
}

impl SimulatedCloud {
    /// The paper's testbed: 16 nodes × 8 cores = 128 cores.
    pub fn tsangpo() -> Arc<SimulatedCloud> {
        SimulatedCloud::new(16 * 8, Duration::from_millis(0))
    }

    pub fn new(
        total_cores: usize,
        provisioning_delay: Duration,
    ) -> Arc<SimulatedCloud> {
        Arc::new(SimulatedCloud {
            total_cores,
            used_cores: Mutex::new(HashMap::new()),
            provisioning_delay,
            next_id: AtomicUsize::new(0),
        })
    }
}

impl CloudProvider for SimulatedCloud {
    fn acquire_vm(&self, class: VmClass) -> Result<VmHandle> {
        let mut used = self.used_cores.lock().expect("cloud poisoned");
        let in_use: usize = used.values().sum();
        if in_use + class.cores() > self.total_cores {
            return Err(FloeError::Resource(format!(
                "cloud: capacity exhausted ({in_use}/{} cores in use)",
                self.total_cores
            )));
        }
        let id = format!("vm-{}", self.next_id.fetch_add(1, Ordering::SeqCst));
        used.insert(id.clone(), class.cores());
        drop(used);
        if !self.provisioning_delay.is_zero() {
            std::thread::sleep(self.provisioning_delay);
        }
        crate::log_info!("cloud: provisioned {id} ({:?})", class);
        Ok(VmHandle { id, class })
    }

    fn release_vm(&self, id: &str) -> Result<()> {
        let mut used = self.used_cores.lock().expect("cloud poisoned");
        used.remove(id).ok_or_else(|| {
            FloeError::Resource(format!("cloud: unknown vm '{id}'"))
        })?;
        crate::log_info!("cloud: released {id}");
        Ok(())
    }

    fn active_vms(&self) -> usize {
        self.used_cores.lock().expect("cloud poisoned").len()
    }

    fn capacity_cores(&self) -> usize {
        self.total_cores
    }
}

/// The manager: owns containers on acquired VMs and serves the
/// coordinator's core requests with best-fit packing (§III: "request
/// existing or newly instantiated containers from the manager using a
/// best-fit algorithm").
pub struct ResourceManager {
    cloud: Arc<dyn CloudProvider>,
    default_class: VmClass,
    inner: Mutex<MgrInner>,
}

struct MgrInner {
    /// (vm id, container) pairs.
    containers: Vec<(String, Arc<Container>)>,
}

impl ResourceManager {
    pub fn new(cloud: Arc<dyn CloudProvider>) -> Arc<ResourceManager> {
        Arc::new(ResourceManager {
            cloud,
            default_class: VmClass::ExtraLarge,
            inner: Mutex::new(MgrInner { containers: Vec::new() }),
        })
    }

    /// Find the container whose free-core count is the *smallest* that
    /// still fits `cores` (best fit).  Acquires a new VM when nothing
    /// fits.
    pub fn allocate(&self, cores: usize) -> Result<Arc<Container>> {
        self.allocate_where(cores, None)
    }

    /// Best-fit allocation that skips one container — used by flake
    /// relocation, where the replacement must land on a *different*
    /// container than the one it is leaving.  Acquires a new VM when no
    /// other container fits.
    pub fn allocate_avoiding(
        &self,
        cores: usize,
        avoid_container: &str,
    ) -> Result<Arc<Container>> {
        self.allocate_where(cores, Some(avoid_container))
    }

    /// Shared placement policy behind [`ResourceManager::allocate`] and
    /// [`ResourceManager::allocate_avoiding`].
    fn allocate_where(
        &self,
        cores: usize,
        avoid_container: Option<&str>,
    ) -> Result<Arc<Container>> {
        let mut inner = self.inner.lock().expect("manager poisoned");
        let best = inner
            .containers
            .iter()
            .filter(|(_, c)| {
                avoid_container != Some(c.id.as_str())
                    && !c.is_dead()
                    && c.free_cores() >= cores
            })
            .min_by_key(|(_, c)| c.free_cores())
            .map(|(_, c)| Arc::clone(c));
        if let Some(c) = best {
            return Ok(c);
        }
        // Need a new VM; pick a class large enough.
        let class = if cores <= self.default_class.cores() {
            self.default_class
        } else {
            return Err(FloeError::Resource(format!(
                "manager: no VM class with {cores} cores"
            )));
        };
        let vm = self.cloud.acquire_vm(class)?;
        let container = Container::new(
            format!("container-{}", vm.id),
            class.cores(),
        );
        inner.containers.push((vm.id, Arc::clone(&container)));
        Ok(container)
    }

    /// All live containers.
    pub fn containers(&self) -> Vec<Arc<Container>> {
        self.inner
            .lock()
            .expect("manager poisoned")
            .containers
            .iter()
            .map(|(_, c)| Arc::clone(c))
            .collect()
    }

    /// Evict a dead container: drop it from the pool and release its
    /// VM (failure repair's final step — the replacement flakes are
    /// already live elsewhere, so nothing on it is worth draining).
    /// Unknown ids are a no-op: a repair retried across ticks may race
    /// a previous eviction.
    pub fn evict(&self, container_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("manager poisoned");
        let Some(pos) = inner
            .containers
            .iter()
            .position(|(_, c)| c.id == container_id)
        else {
            return Ok(());
        };
        let (vm, c) = inner.containers.remove(pos);
        drop(inner);
        c.shutdown();
        self.cloud.release_vm(&vm)?;
        crate::log_info!(
            "manager: evicted dead container '{container_id}' (vm {vm})"
        );
        Ok(())
    }

    /// Release empty containers back to the cloud (scale-in).
    pub fn release_idle(&self) -> Result<usize> {
        let mut inner = self.inner.lock().expect("manager poisoned");
        let mut released = 0;
        let mut keep = Vec::new();
        for (vm, c) in inner.containers.drain(..) {
            if c.flake_count() == 0 {
                self.cloud.release_vm(&vm)?;
                released += 1;
            } else {
                keep.push((vm, c));
            }
        }
        inner.containers = keep;
        Ok(released)
    }

    /// Tear down every container and release every VM.
    pub fn shutdown(&self) {
        let mut inner = self.inner.lock().expect("manager poisoned");
        for (vm, c) in inner.containers.drain(..) {
            c.shutdown();
            let _ = self.cloud.release_vm(&vm);
        }
    }

    /// JSON status for the REST endpoint / CLI.
    pub fn status_json(&self) -> Json {
        let inner = self.inner.lock().expect("manager poisoned");
        Json::obj(vec![
            (
                "containers",
                Json::Arr(
                    inner
                        .containers
                        .iter()
                        .map(|(_, c)| c.status_json())
                        .collect(),
                ),
            ),
            ("active_vms", Json::num(self.cloud.active_vms() as f64)),
            (
                "capacity_cores",
                Json::num(self.cloud.capacity_cores() as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloud_capacity_enforced() {
        let cloud = SimulatedCloud::new(8, Duration::ZERO);
        let a = cloud.acquire_vm(VmClass::Large).unwrap();
        let _b = cloud.acquire_vm(VmClass::Large).unwrap();
        assert!(cloud.acquire_vm(VmClass::Small).is_err());
        assert_eq!(cloud.active_vms(), 2);
        cloud.release_vm(&a.id).unwrap();
        assert!(cloud.acquire_vm(VmClass::Small).is_ok());
        assert!(cloud.release_vm("vm-999").is_err());
    }

    #[test]
    fn best_fit_prefers_fullest_container() {
        let cloud = SimulatedCloud::new(128, Duration::ZERO);
        let mgr = ResourceManager::new(cloud);
        // First allocation provisions a VM (8 cores).
        let c1 = mgr.allocate(5).unwrap();
        let _f = spawn_dummy(&c1, "a", 5);
        // 3 cores free on c1; a 2-core ask should best-fit onto c1, not a
        // fresh container.
        let c2 = mgr.allocate(2).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2));
        // A 4-core ask does not fit c1 -> new VM.
        let c3 = mgr.allocate(4).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c3));
        assert_eq!(mgr.containers().len(), 2);
        mgr.shutdown();
    }

    #[test]
    fn allocate_avoiding_skips_named_container() {
        let cloud = SimulatedCloud::new(128, Duration::ZERO);
        let mgr = ResourceManager::new(cloud);
        let c1 = mgr.allocate(2).unwrap();
        // Plenty of room on c1, but relocation must leave it.
        let c2 = mgr.allocate_avoiding(2, &c1.id).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c2));
        // A second avoiding ask best-fits onto the existing other VM.
        let c3 = mgr.allocate_avoiding(2, &c1.id).unwrap();
        assert!(Arc::ptr_eq(&c2, &c3));
        mgr.shutdown();
    }

    #[test]
    fn release_idle_returns_vms() {
        let cloud = SimulatedCloud::new(64, Duration::ZERO);
        let mgr = ResourceManager::new(
            Arc::clone(&cloud) as Arc<dyn CloudProvider>
        );
        let c = mgr.allocate(2).unwrap();
        assert_eq!(cloud.active_vms(), 1);
        // Container is empty -> released.
        assert_eq!(mgr.release_idle().unwrap(), 1);
        assert_eq!(cloud.active_vms(), 0);
        drop(c);
        mgr.shutdown();
    }

    fn spawn_dummy(
        c: &Arc<Container>,
        id: &str,
        cores: usize,
    ) -> Arc<crate::flake::Flake> {
        use crate::graph::{
            InPortSpec, MergeMode, OutPortSpec, SplitMode, TriggerMode,
            WindowSpec,
        };
        let cfg = crate::flake::FlakeConfig {
            pellet_id: id.into(),
            class: "floe.builtin.Identity".into(),
            inputs: vec![InPortSpec {
                name: "in".into(),
                window: WindowSpec::None,
            }],
            outputs: vec![OutPortSpec {
                name: "out".into(),
                split: SplitMode::RoundRobin,
            }],
            merge: MergeMode::Interleaved,
            trigger: TriggerMode::Push,
            sequential: false,
            stateful: false,
            cores,
            alpha: 1,
            queue_capacity: 16,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: 2,
            channel_backend: crate::channel::ChannelBackend::default(),
            dedup: false,
        };
        c.spawn_flake(
            cfg,
            Arc::new(|| Box::new(crate::pellet::builtins::Identity)),
        )
        .unwrap()
    }
}
