//! # Floe-RS — a continuous dataflow framework for dynamic cloud applications
//!
//! Rust reproduction of *"Floe: A Continuous Dataflow Framework for Dynamic
//! Cloud Applications"* (Simmhan & Kumbhare, 2014), with the stream-clustering
//! numeric hot-spot AOT-compiled from JAX/Pallas and executed through PJRT.
//!
//! Applications are directed (possibly cyclic) graphs of **pellets** — user
//! tasks implementing push or pull [`pellet::Pellet`] interfaces — connected
//! by data channels.  The runtime maps each pellet onto a [`flake::Flake`]
//! (per-pellet executor with data-parallel instances), flakes onto
//! [`container::Container`]s (VM-granularity core accounting), and adapts the
//! per-flake core allocation at runtime with the strategies in
//! [`adaptation`] (static look-ahead / dynamic / hybrid).  The
//! [`coordinator::Coordinator`] parses graphs, places flakes via the
//! [`manager`] resource manager, wires them bottom-up, and orchestrates
//! in-place dynamic task and dataflow updates without stopping the stream.
//! The [`recompose`] engine goes further and performs live graph surgery:
//! structural deltas (insert/remove pellets and edges, relocate flakes
//! across containers) applied to the running topology with a minimal
//! pause set and zero message loss.  The data plane is
//! **location-transparent**: every flake input port has a stable
//! logical address (`floe://<flake>/<port>`) resolved through a
//! versioned [`channel::EndpointTable`], so relocation — including of
//! TCP-fed flakes — is a republish that every sender follows live.  The
//! [`adaptation::elastic::ElasticityPolicy`] closes the loop between
//! the two: strategy decisions regrant cores in place, and sustained
//! container saturation escalates to a recompose-driven flake
//! migration — verified deterministically by the seeded workload
//! driver in [`sim::driver`].  Dataflows are **self-healing**: every
//! launch knob lives in the builder-style
//! [`coordinator::RuntimeOptions`], and enabling its
//! [`coordinator::FaultToleranceConfig`] starts per-container
//! heartbeats, a coordinator-side lease detector, and periodic
//! checkpoints; a container that stops beating is declared dead and
//! its flakes are re-spawned elsewhere via a `ReplaceFailed` delta —
//! restored from their last checkpoint, endpoints republished so every
//! sender re-routes live — without quiescing the survivors.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for the
//! reproduced evaluation.

pub mod adaptation;
pub mod apps;
pub mod channel;
pub mod chaos;
pub mod container;
pub mod coordinator;
pub mod error;
pub mod flake;
pub mod graph;
pub mod manager;
pub mod message;
pub mod pellet;
pub mod recompose;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod util;

pub use error::{FloeError, Result};

/// Instances-per-core ratio α from the paper (§III): each core granted to a
/// flake runs up to α data-parallel pellet instances.
pub const ALPHA: usize = 4;

/// One-stop imports for applications and examples.
pub mod prelude {
    pub use crate::adaptation::{
        AdaptationStrategy, DynamicStrategy, ElasticityConfig,
        ElasticityPolicy, HybridStrategy, StaticLookAhead,
    };
    pub use crate::channel::{ChannelBackend, EndpointAddr, EndpointTable};
    pub use crate::coordinator::{
        Coordinator, DataflowStats, FailureEvent, FaultToleranceConfig,
        LeaseTracker, RepairEvent, RuntimeOptions,
    };
    pub use crate::error::{FloeError, Result};
    pub use crate::graph::{DataflowGraph, GraphBuilder, SplitMode};
    pub use crate::manager::{ResourceManager, SimulatedCloud};
    pub use crate::message::Message;
    pub use crate::pellet::{
        Pellet, PelletContext, PelletFactory, PelletRegistry, PortIo,
    };
    pub use crate::recompose::{DeltaOp, GraphDelta, RecomposeStats};
    pub use crate::telemetry::TelemetryConfig;
    pub use crate::ALPHA;
}
