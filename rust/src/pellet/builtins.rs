//! Built-in pellet classes (`floe.builtin.*`): identity/relay, map/filter
//! over text and vectors, key extraction, rate metering, sequence sources
//! and collecting sinks.  They serve examples, tests and as reference
//! implementations of the push/pull interfaces.

use std::sync::atomic::AtomicU64;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use super::{Pellet, PelletContext, PelletRegistry, PortIo, PullSource};
use crate::error::Result;
use crate::message::{Landmark, Message};
use crate::util::json::Json;

/// Forward every message unchanged (`floe.builtin.Identity`).
pub struct Identity;

impl Pellet for Identity {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        match input {
            PortIo::Single(_, m) => ctx.emit("out", m),
            PortIo::Tuple(t) => {
                for (_, m) in t.iter() {
                    ctx.emit("out", m.clone());
                }
            }
            PortIo::Window(_, v) => {
                for m in v {
                    ctx.emit("out", m);
                }
            }
        }
        Ok(())
    }
}

/// Uppercase text messages (`floe.builtin.Uppercase`).
pub struct Uppercase;

impl Pellet for Uppercase {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if let Some(t) = m.as_text() {
                let mut out = Message::text(t.to_uppercase());
                out.key = m.key.clone();
                ctx.emit("out", out);
            }
        }
        Ok(())
    }
}

/// Double every f32 element (`floe.builtin.MapDouble`).
pub struct MapDouble;

impl Pellet for MapDouble {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if let Some(v) = m.as_f32s() {
                ctx.emit(
                    "out",
                    Message::f32s(v.iter().map(|x| x * 2.0).collect()),
                );
            }
        }
        Ok(())
    }
}

/// Drop messages whose text does not contain the configured needle
/// (`floe.builtin.FilterContains`; needle in state key `needle`).
pub struct FilterContains;

impl Pellet for FilterContains {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let needle = ctx
            .state()
            .get("needle")
            .and_then(|j| j.as_str().map(|s| s.to_string()))
            .unwrap_or_default();
        for m in input.messages() {
            if m.as_text().map(|t| t.contains(&needle)).unwrap_or(false) {
                ctx.emit("out", m.clone());
            }
        }
        Ok(())
    }
}

/// Split text into words and emit each keyed by the word — the mapper half
/// of streaming word count (`floe.builtin.WordSplit`).
pub struct WordSplit;

impl Pellet for WordSplit {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                ctx.emit("out", m.clone());
                continue;
            }
            if let Some(t) = m.as_text() {
                for w in t.split_whitespace() {
                    let word = w.to_lowercase();
                    ctx.emit("out", Message::text(word.clone()).with_key(word));
                }
            }
        }
        Ok(())
    }
}

/// Count keyed messages; on a WindowEnd landmark emit `key=count` text
/// lines — the reducer half of streaming word count
/// (`floe.builtin.KeyCount`).  Stateful.
pub struct KeyCount;

impl Pellet for KeyCount {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if let Some(Landmark::WindowEnd(_)) = m.landmark {
                // Drain-and-emit: counts are per window, and draining keeps
                // totals correct when landmarks arrive once per upstream
                // mapper rather than once per window.
                let snap = ctx.state().snapshot();
                for (k, v) in snap {
                    if let Some(n) = v.as_f64() {
                        if n > 0.0 {
                            ctx.emit(
                                "out",
                                Message::text(format!("{k}={n}"))
                                    .with_key(k.clone()),
                            );
                        }
                        ctx.state().remove(&k);
                    }
                }
                continue;
            }
            if let Some(k) = m.key.clone() {
                ctx.state().update_num(&k, |c| c + 1.0);
            }
        }
        Ok(())
    }
}

/// Pull-mode running mean over f32 vectors: consumes the whole stream,
/// emits one mean vector per WindowEnd landmark
/// (`floe.builtin.RunningMean`).
pub struct RunningMean {
    sum: Vec<f32>,
    n: usize,
}

impl RunningMean {
    pub fn new() -> Self {
        RunningMean { sum: vec![], n: 0 }
    }
}

impl Pellet for RunningMean {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        for m in input.messages() {
            if m.is_landmark() {
                if self.n > 0 {
                    let mean: Vec<f32> = self
                        .sum
                        .iter()
                        .map(|s| s / self.n as f32)
                        .collect();
                    ctx.emit("out", Message::f32s(mean));
                    self.sum.clear();
                    self.n = 0;
                }
                continue;
            }
            if let Some(v) = m.as_f32s() {
                if self.sum.len() < v.len() {
                    self.sum.resize(v.len(), 0.0);
                }
                for (s, x) in self.sum.iter_mut().zip(v) {
                    *s += x;
                }
                self.n += 1;
            }
        }
        Ok(())
    }

    fn compute_pull(
        &mut self,
        source: &mut dyn PullSource,
        ctx: &mut PelletContext,
    ) -> Result<()> {
        while let Some(io) = source.next() {
            self.compute(io, ctx)?;
            if ctx.interrupted() {
                break;
            }
        }
        Ok(())
    }
}

/// Collecting sink: appends message text/len to a shared vector for test
/// and example inspection (`floe.builtin.Collect` via [`CollectSink`]).
pub struct CollectSink {
    pub collected: Arc<Mutex<Vec<Message>>>,
}

impl Pellet for CollectSink {
    fn compute(&mut self, input: PortIo, _ctx: &mut PelletContext) -> Result<()> {
        let mut g = self.collected.lock().expect("collect poisoned");
        match input {
            PortIo::Single(_, m) => g.push(m),
            PortIo::Tuple(t) => g.extend(t.values().cloned()),
            PortIo::Window(_, v) => g.extend(v),
        }
        Ok(())
    }
}

/// Counting sink that tracks messages seen in its state object
/// (`floe.builtin.CountSink`); useful when only totals matter.
pub struct CountSink;

impl Pellet for CountSink {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let n = input.messages().len() as f64;
        ctx.state().update_num("count", |c| c + n);
        Ok(())
    }
}

/// Emit `n` sequence text messages `0..n` when triggered by any input
/// message (`floe.builtin.Sequence`; n from state key `n`, default 10).
pub struct Sequence;

impl Pellet for Sequence {
    fn compute(&mut self, _input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let n = ctx
            .state()
            .get("n")
            .and_then(|j| j.as_f64())
            .unwrap_or(10.0) as usize;
        for i in 0..n {
            ctx.emit("out", Message::text(i.to_string()));
        }
        Ok(())
    }
}

/// Sleep for a configured time per message then forward — used to emulate
/// compute-heavy pellets in benchmarks (`floe.builtin.Delay`; seconds in
/// state key `delay_secs`, default 0.001).
pub struct Delay;

impl Pellet for Delay {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let d = ctx
            .state()
            .get("delay_secs")
            .and_then(|j| j.as_f64())
            .unwrap_or(0.001);
        std::thread::sleep(std::time::Duration::from_secs_f64(d));
        for m in input.messages() {
            ctx.emit("out", m.clone());
        }
        Ok(())
    }
}

/// Global emission counter used by RateMeter tests.
pub static METER_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Count throughput into the state object and a process-global counter
/// (`floe.builtin.RateMeter`).
pub struct RateMeter;

impl Pellet for RateMeter {
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext) -> Result<()> {
        let n = input.messages().len() as u64;
        METER_TOTAL.fetch_add(n, Ordering::Relaxed);
        ctx.state().update_num("seen", |c| c + n as f64);
        for m in input.messages() {
            ctx.emit("out", m.clone());
        }
        Ok(())
    }
}

/// Register every `floe.builtin.*` class into a registry.
pub fn register_builtins(r: &PelletRegistry) {
    r.register("floe.builtin.Identity", || Box::new(Identity));
    r.register("floe.builtin.Uppercase", || Box::new(Uppercase));
    r.register("floe.builtin.MapDouble", || Box::new(MapDouble));
    r.register("floe.builtin.FilterContains", || Box::new(FilterContains));
    r.register("floe.builtin.WordSplit", || Box::new(WordSplit));
    r.register("floe.builtin.KeyCount", || Box::new(KeyCount));
    r.register("floe.builtin.RunningMean", || Box::new(RunningMean::new()));
    r.register("floe.builtin.CountSink", || Box::new(CountSink));
    r.register("floe.builtin.Sequence", || Box::new(Sequence));
    r.register("floe.builtin.Delay", || Box::new(Delay));
    r.register("floe.builtin.RateMeter", || Box::new(RateMeter));
}

/// Set up `floe.builtin.FilterContains` state: store the needle.
pub fn configure_filter(state: &super::StateObject, needle: &str) {
    state.set("needle", Json::str(needle));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pellet::StateObject;
    use std::sync::atomic::AtomicBool;

    fn ctx_with(state: StateObject) -> PelletContext {
        PelletContext::new(
            "p",
            0,
            1,
            state,
            Arc::new(AtomicBool::new(false)),
        )
    }

    fn push1(p: &mut dyn Pellet, m: Message) -> Vec<(String, Message)> {
        let mut c = ctx_with(StateObject::new());
        p.compute(PortIo::Single("in".into(), m), &mut c).unwrap();
        c.take_emitted()
    }

    #[test]
    fn identity_forwards() {
        let out = push1(&mut Identity, Message::text("x"));
        assert_eq!(out[0].1.as_text(), Some("x"));
    }

    #[test]
    fn uppercase_keeps_key() {
        let out = push1(&mut Uppercase, Message::text("abc").with_key("k"));
        assert_eq!(out[0].1.as_text(), Some("ABC"));
        assert_eq!(out[0].1.key.as_deref(), Some("k"));
    }

    #[test]
    fn word_split_emits_keyed_words() {
        let out = push1(&mut WordSplit, Message::text("To be OR not"));
        let words: Vec<_> =
            out.iter().map(|(_, m)| m.as_text().unwrap()).collect();
        assert_eq!(words, vec!["to", "be", "or", "not"]);
        assert!(out.iter().all(|(_, m)| m.key.is_some()));
    }

    #[test]
    fn key_count_aggregates_until_landmark() {
        let mut p = KeyCount;
        let state = StateObject::new();
        let mut c = ctx_with(state.clone());
        for k in ["a", "b", "a"] {
            p.compute(
                PortIo::Single("in".into(), Message::text(k).with_key(k)),
                &mut c,
            )
            .unwrap();
        }
        assert!(c.take_emitted().is_empty());
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::landmark(Landmark::WindowEnd("w".into())),
            ),
            &mut c,
        )
        .unwrap();
        let mut lines: Vec<_> = c
            .take_emitted()
            .iter()
            .map(|(_, m)| m.as_text().unwrap().to_string())
            .collect();
        lines.sort();
        assert_eq!(lines, vec!["a=2", "b=1"]);
    }

    #[test]
    fn running_mean_on_landmark() {
        let mut p = RunningMean::new();
        let mut c = ctx_with(StateObject::new());
        p.compute(
            PortIo::Single("in".into(), Message::f32s(vec![1.0, 2.0])),
            &mut c,
        )
        .unwrap();
        p.compute(
            PortIo::Single("in".into(), Message::f32s(vec![3.0, 4.0])),
            &mut c,
        )
        .unwrap();
        p.compute(
            PortIo::Single(
                "in".into(),
                Message::landmark(Landmark::WindowEnd("w".into())),
            ),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.as_f32s(), Some(&[2.0f32, 3.0][..]));
    }

    #[test]
    fn filter_contains_uses_state() {
        let mut p = FilterContains;
        let state = StateObject::new();
        configure_filter(&state, "keep");
        let mut c = ctx_with(state);
        p.compute(
            PortIo::Single("in".into(), Message::text("keep me")),
            &mut c,
        )
        .unwrap();
        p.compute(
            PortIo::Single("in".into(), Message::text("drop me")),
            &mut c,
        )
        .unwrap();
        assert_eq!(c.take_emitted().len(), 1);
    }

    #[test]
    fn count_sink_counts() {
        let mut p = CountSink;
        let state = StateObject::new();
        let mut c = ctx_with(state.clone());
        p.compute(
            PortIo::Window(
                "in".into(),
                vec![Message::empty(), Message::empty()],
            ),
            &mut c,
        )
        .unwrap();
        assert_eq!(state.get("count"), Some(Json::Num(2.0)));
    }

    #[test]
    fn builtins_all_registered() {
        let r = PelletRegistry::with_builtins();
        for class in [
            "floe.builtin.Identity",
            "floe.builtin.Uppercase",
            "floe.builtin.MapDouble",
            "floe.builtin.FilterContains",
            "floe.builtin.WordSplit",
            "floe.builtin.KeyCount",
            "floe.builtin.RunningMean",
            "floe.builtin.CountSink",
            "floe.builtin.Sequence",
            "floe.builtin.Delay",
            "floe.builtin.RateMeter",
        ] {
            assert!(r.resolve(class).is_ok(), "{class}");
        }
    }
}
