//! Pellets: the user's application logic (§II-A).
//!
//! A pellet implements [`Pellet`] with either push triggering (the framework
//! calls [`Pellet::compute`] once per input) or pull triggering
//! ([`Pellet::compute_pull`] iterates over the input stream and may consume
//! zero or more messages per emit).  Pellets see their inputs as [`PortIo`]
//! values — a single message, a port-indexed tuple from a synchronous
//! merge, or a window of messages.
//!
//! State is kept in an explicit [`StateObject`] that the framework retains
//! across invocations *and across in-place dynamic updates*, enabling the
//! paper's zero-downtime task swap and (future) checkpoint-based resilience.

pub mod builtins;

pub use builtins::register_builtins;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::error::{FloeError, Result};
use crate::message::Message;
use crate::util::json::Json;

/// Input delivered to a pellet invocation.
#[derive(Debug, Clone)]
pub enum PortIo {
    /// One message from one input port: `(port name, message)`.
    Single(String, Message),
    /// Synchronous merge: one message per input port, indexed by port name
    /// (Fig. 1, P5).
    Tuple(BTreeMap<String, Message>),
    /// A count/time window of messages from one port (Fig. 1, P3).
    Window(String, Vec<Message>),
}

impl PortIo {
    /// The messages inside, regardless of shape.
    pub fn messages(&self) -> Vec<&Message> {
        match self {
            PortIo::Single(_, m) => vec![m],
            PortIo::Tuple(t) => t.values().collect(),
            PortIo::Window(_, v) => v.iter().collect(),
        }
    }

    /// Port name for Single/Window inputs.
    pub fn port(&self) -> Option<&str> {
        match self {
            PortIo::Single(p, _) | PortIo::Window(p, _) => Some(p),
            PortIo::Tuple(_) => None,
        }
    }

    /// Convenience for the common Single case.
    pub fn single(self) -> Option<Message> {
        match self {
            PortIo::Single(_, m) => Some(m),
            _ => None,
        }
    }
}

/// Explicit pellet state (§II-A): a JSON-valued key-value object shared by
/// all data-parallel instances of a pellet and surviving dynamic updates.
#[derive(Clone, Default)]
pub struct StateObject {
    inner: Arc<Mutex<BTreeMap<String, Json>>>,
}

impl StateObject {
    pub fn new() -> Self {
        StateObject::default()
    }

    pub fn get(&self, key: &str) -> Option<Json> {
        self.inner.lock().expect("state poisoned").get(key).cloned()
    }

    pub fn set(&self, key: &str, value: Json) {
        self.inner
            .lock()
            .expect("state poisoned")
            .insert(key.to_string(), value);
    }

    pub fn remove(&self, key: &str) -> Option<Json> {
        self.inner.lock().expect("state poisoned").remove(key)
    }

    /// Numeric read-modify-write (counters, running sums).
    pub fn update_num(&self, key: &str, f: impl FnOnce(f64) -> f64) -> f64 {
        let mut g = self.inner.lock().expect("state poisoned");
        let cur = g.get(key).and_then(|j| j.as_f64()).unwrap_or(0.0);
        let next = f(cur);
        g.insert(key.to_string(), Json::Num(next));
        next
    }

    /// Snapshot for checkpointing (future resilience work) and tests.
    pub fn snapshot(&self) -> BTreeMap<String, Json> {
        self.inner.lock().expect("state poisoned").clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("state poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Execution context handed to every pellet invocation: output emission,
/// the state object, interrupt checks, and identity.
pub struct PelletContext {
    /// Pellet id in the graph.
    pub pellet_id: String,
    /// Data-parallel instance index.
    pub instance: usize,
    /// Logic version (bumped by dynamic updates).
    pub version: u64,
    state: StateObject,
    interrupted: Arc<AtomicBool>,
    /// Fast path for push pellets: plain buffer, no locking.
    emitted_local: Vec<(String, Message)>,
    /// Opt-in shared buffer (see [`PelletContext::emission_buffer`]) so
    /// the flake can flush a long-running pull pellet's output while
    /// `compute_pull` is still iterating.
    emitted_shared: Option<Arc<Mutex<Vec<(String, Message)>>>>,
}

impl PelletContext {
    pub fn new(
        pellet_id: impl Into<String>,
        instance: usize,
        version: u64,
        state: StateObject,
        interrupted: Arc<AtomicBool>,
    ) -> Self {
        PelletContext {
            pellet_id: pellet_id.into(),
            instance,
            version,
            state,
            interrupted,
            emitted_local: Vec::new(),
            emitted_shared: None,
        }
    }

    /// Emit a message on a named output port.
    pub fn emit(&mut self, port: impl Into<String>, msg: Message) {
        match &self.emitted_shared {
            None => self.emitted_local.push((port.into(), msg)),
            Some(s) => s
                .lock()
                .expect("emit buffer poisoned")
                .push((port.into(), msg)),
        }
    }

    /// The pellet's state object (stateful pellets).
    pub fn state(&self) -> &StateObject {
        &self.state
    }

    /// True when the framework asks this instance to wrap up (synchronous
    /// dynamic update of a long-running pellet — the paper's
    /// `InterruptException` equivalent).
    pub fn interrupted(&self) -> bool {
        self.interrupted.load(Ordering::Relaxed)
    }

    /// Drain emitted messages (framework side).
    pub fn take_emitted(&mut self) -> Vec<(String, Message)> {
        match &self.emitted_shared {
            None => std::mem::take(&mut self.emitted_local),
            Some(s) => {
                let mut out = std::mem::take(
                    &mut *s.lock().expect("emit poisoned"),
                );
                if !self.emitted_local.is_empty() {
                    out.append(&mut self.emitted_local);
                }
                out
            }
        }
    }

    /// Switch this context to a shared emission buffer and return the
    /// handle — lets the flake flush output from a pull pellet that is
    /// still inside `compute_pull`.  Push pellets never pay the lock.
    pub fn emission_buffer(
        &mut self,
    ) -> Arc<Mutex<Vec<(String, Message)>>> {
        let shared = self
            .emitted_shared
            .get_or_insert_with(|| Arc::new(Mutex::new(Vec::new())));
        if !self.emitted_local.is_empty() {
            shared
                .lock()
                .expect("emit poisoned")
                .append(&mut self.emitted_local);
        }
        Arc::clone(shared)
    }
}

/// Provider of input for pull pellets: blocks for the next input, returns
/// `None` when the stream ends or the framework needs the instance to yield
/// (pause, update, shutdown).
pub trait PullSource {
    fn next(&mut self) -> Option<PortIo>;
}

impl<F: FnMut() -> Option<PortIo>> PullSource for F {
    fn next(&mut self) -> Option<PortIo> {
        self()
    }
}

/// The pellet interface (§II-A's family of `compute()` interfaces).
///
/// Push pellets implement [`Pellet::compute`]; pull pellets implement
/// [`Pellet::compute_pull`].  The default `compute_pull` drains the source
/// through `compute`, so a push pellet works under either trigger mode.
pub trait Pellet: Send {
    /// One-time setup when an instance is created (open connections, load
    /// dictionaries...).
    fn setup(&mut self, _ctx: &mut PelletContext) -> Result<()> {
        Ok(())
    }

    /// Push triggering: handle one input, emit via `ctx.emit`.
    fn compute(&mut self, input: PortIo, ctx: &mut PelletContext)
        -> Result<()>;

    /// Pull triggering: iterate the stream.  Instances should poll
    /// `ctx.interrupted()` between messages and return promptly when set.
    fn compute_pull(
        &mut self,
        source: &mut dyn PullSource,
        ctx: &mut PelletContext,
    ) -> Result<()> {
        while let Some(input) = source.next() {
            self.compute(input, ctx)?;
            if ctx.interrupted() {
                break;
            }
        }
        Ok(())
    }

    /// Teardown before the instance is dropped (including on update).
    fn teardown(&mut self, _ctx: &mut PelletContext) {}
}

/// Factory producing pellet instances — the unit swapped by dynamic task
/// updates.  Qualified class names (paper: Java class names) map to
/// factories through the [`PelletRegistry`].
pub type PelletFactory = Arc<dyn Fn() -> Box<dyn Pellet> + Send + Sync>;

/// Registry of pellet classes by qualified name.
#[derive(Clone, Default)]
pub struct PelletRegistry {
    inner: Arc<RwLock<BTreeMap<String, PelletFactory>>>,
}

impl PelletRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        PelletRegistry::default()
    }

    /// Registry pre-loaded with `floe.builtin.*` classes.
    pub fn with_builtins() -> Self {
        let r = PelletRegistry::new();
        register_builtins(&r);
        r
    }

    /// Register (or replace) a class.  Replacement is the mechanism behind
    /// dynamic task updates driven by class name.
    pub fn register<F>(&self, class: &str, factory: F)
    where
        F: Fn() -> Box<dyn Pellet> + Send + Sync + 'static,
    {
        self.inner
            .write()
            .expect("registry poisoned")
            .insert(class.to_string(), Arc::new(factory));
    }

    /// Look up a class factory.
    pub fn resolve(&self, class: &str) -> Result<PelletFactory> {
        self.inner
            .read()
            .expect("registry poisoned")
            .get(class)
            .cloned()
            .ok_or_else(|| {
                FloeError::Graph(format!("unknown pellet class '{class}'"))
            })
    }

    pub fn classes(&self) -> Vec<String> {
        self.inner
            .read()
            .expect("registry poisoned")
            .keys()
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Doubler;
    impl Pellet for Doubler {
        fn compute(
            &mut self,
            input: PortIo,
            ctx: &mut PelletContext,
        ) -> Result<()> {
            if let PortIo::Single(_, m) = input {
                let v: Vec<f32> = m
                    .as_f32s()
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x * 2.0)
                    .collect();
                ctx.emit("out", Message::f32s(v));
            }
            Ok(())
        }
    }

    fn ctx() -> PelletContext {
        PelletContext::new(
            "p",
            0,
            1,
            StateObject::new(),
            Arc::new(AtomicBool::new(false)),
        )
    }

    #[test]
    fn push_compute_emits() {
        let mut p = Doubler;
        let mut c = ctx();
        p.compute(
            PortIo::Single("in".into(), Message::f32s(vec![1.0, 2.0])),
            &mut c,
        )
        .unwrap();
        let out = c.take_emitted();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, "out");
        assert_eq!(out[0].1.as_f32s(), Some(&[2.0f32, 4.0][..]));
        assert!(c.take_emitted().is_empty());
    }

    #[test]
    fn default_pull_drains_source() {
        let mut p = Doubler;
        let mut c = ctx();
        let mut items = vec![
            PortIo::Single("in".into(), Message::f32s(vec![1.0])),
            PortIo::Single("in".into(), Message::f32s(vec![3.0])),
        ]
        .into_iter();
        let mut source = || items.next();
        p.compute_pull(&mut source, &mut c).unwrap();
        assert_eq!(c.take_emitted().len(), 2);
    }

    #[test]
    fn pull_respects_interrupt() {
        let mut p = Doubler;
        let flag = Arc::new(AtomicBool::new(false));
        let mut c = PelletContext::new(
            "p",
            0,
            1,
            StateObject::new(),
            Arc::clone(&flag),
        );
        flag.store(true, Ordering::Relaxed);
        let mut _count = 0;
        let mut source = move || {
            _count += 1;
            Some(PortIo::Single("in".into(), Message::f32s(vec![1.0])))
        };
        p.compute_pull(&mut source, &mut c).unwrap();
        // interrupted after the first message
        assert_eq!(c.take_emitted().len(), 1);
    }

    #[test]
    fn state_object_shared_and_updatable() {
        let s = StateObject::new();
        let s2 = s.clone();
        s.set("k", Json::Num(1.0));
        assert_eq!(s2.get("k"), Some(Json::Num(1.0)));
        let v = s2.update_num("k", |x| x + 2.0);
        assert_eq!(v, 3.0);
        assert_eq!(s.get("k"), Some(Json::Num(3.0)));
        assert_eq!(s.snapshot().len(), 1);
        s.remove("k");
        assert!(s.is_empty());
    }

    #[test]
    fn registry_resolves_and_replaces() {
        let r = PelletRegistry::new();
        r.register("t.Doubler", || Box::new(Doubler));
        let f = r.resolve("t.Doubler").unwrap();
        let _p = f();
        assert!(r.resolve("t.Nope").is_err());
        // replacement (dynamic task update by class)
        r.register("t.Doubler", || Box::new(Doubler));
        assert_eq!(r.classes(), vec!["t.Doubler"]);
    }

    #[test]
    fn portio_accessors() {
        let s = PortIo::Single("a".into(), Message::text("x"));
        assert_eq!(s.port(), Some("a"));
        assert_eq!(s.messages().len(), 1);
        let mut map = BTreeMap::new();
        map.insert("p1".to_string(), Message::text("1"));
        map.insert("p2".to_string(), Message::text("2"));
        let t = PortIo::Tuple(map);
        assert_eq!(t.port(), None);
        assert_eq!(t.messages().len(), 2);
        let w = PortIo::Window(
            "w".into(),
            vec![Message::empty(), Message::empty()],
        );
        assert_eq!(w.messages().len(), 2);
        assert!(w.single().is_none());
    }
}
