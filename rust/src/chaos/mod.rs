//! Deterministic fault injection over the network substrate.
//!
//! Every failover test before this module killed a container cleanly;
//! nothing ever dropped, delayed, duplicated, corrupted, or
//! partitioned a byte in flight.  This module is the adversary: a
//! [`FaultPlan`] compiled from a seed + [`FaultSpec`] into an *exact*
//! schedule of faults, consulted by the transport seams
//! ([`crate::channel::TcpSender`], the `RxConn`/`RxListener` state
//! machines, [`crate::container::Container::heartbeat`]) through the
//! process-global hook below.
//!
//! Determinism is the whole point: every frame-level decision is a
//! pure function of `(seed, link, frame_index)` — independent of
//! thread interleaving, wall clock, and batch boundaries — so a
//! failing run reproduces from its printed seed alone, and the
//! schedule byte-serializes for property tests
//! ([`FaultPlan::schedule_bytes`]).  Time-window faults (partitions,
//! read stalls) are relative to the instant the plan was armed.
//!
//! The hook costs one relaxed atomic load when no plan is armed; the
//! hot path stays untouched in production.
//!
//! Fault semantics (chosen so the suite can assert *exact* outcomes
//! against the at-least-once + dedup delivery contract):
//!
//! * **drop** — the frame's first transmission is lost with its
//!   connection: the sender cuts (with a drain handshake, so earlier
//!   frames finish delivery first) and the retry loop resends the
//!   frame on a fresh connection.  Zero loss, per-producer FIFO.
//! * **delay** — the sender stalls `delay_ms` while framing, before
//!   the batch is enqueued for transmission.
//! * **duplicate** — the frame is transmitted twice back-to-back; the
//!   receiver-side dedup watermark drops the echo.
//! * **reorder** — a stale copy of the *previous* frame is
//!   retransmitted after the current one (the only reordering a
//!   connection-oriented transport can exhibit: a late replay across
//!   a reconnect).  The dedup watermark absorbs it.
//! * **corrupt** — one byte of the framed bytes is flipped after the
//!   checksum trailer is computed; the receiver detects the mismatch,
//!   counts it, drops the frame, and closes the connection
//!   (drop-frame-and-reconnect, never a misparse).
//! * **reset** — the sender's connection is torn down abruptly before
//!   a batch; the retry loop reconnects.
//! * **refuse** — the listener accepts and immediately closes (a
//!   crashing peer); the sender's write fails and retries.
//! * **read stall** — receivers stop reading for a window (a
//!   half-open peer: accepted, never reads); kernel buffers absorb
//!   in-flight bytes and the sender's write-stall deadline bounds
//!   how long the egress pipeline waits for writability.
//! * **partition** — a container-pair window during which heartbeats
//!   between the pair freeze (the coordinator side is
//!   [`COORDINATOR`]): lease expiry driven by *delayed* beats from a
//!   live husk, not only dead ones.
//!
//! On the pipelined egress path, sender-side faults are *decided* at
//! framing/enqueue time — the decision indices
//! (per-sender monotone frame and batch counters) are identical to
//! the old synchronous path, so pinned seeds replay the same fault
//! schedule — and *applied* at the right point in the byte stream:
//! drop/reset cuts travel through the egress queue as cut markers
//! that sever the connection (drain handshake included) exactly
//! between the batches they were injected between, before anything
//! later is enqueued to the kernel, so the resend stays in order.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

use crate::util::rng::Rng;

/// Wildcard endpoint for partition windows: matches any container.
pub const ANY: &str = "*";

/// The coordinator's identity in a partition window — pairing a
/// container with this stalls its heartbeat as observed by the
/// failure detector.
pub const COORDINATOR: &str = "@coordinator";

// ---------------------------------------------------------------------------
// Spec
// ---------------------------------------------------------------------------

/// A container-pair partition window, in milliseconds since the plan
/// was armed.  Sides match unordered; [`ANY`] is a wildcard.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionSpec {
    pub a: String,
    pub b: String,
    pub start_ms: u64,
    pub dur_ms: u64,
}

/// Declarative fault mix.  Probabilities are per-frame (or per-batch
/// for `reset`, per-accept for `refuse`); windows are relative to arm
/// time.  Build with the chained setters:
///
/// ```
/// use floe::chaos::FaultSpec;
/// let spec = FaultSpec::new()
///     .drop(0.05)
///     .delay(0.10, 2)
///     .duplicate(0.05)
///     .reorder(0.05);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    pub drop_p: f64,
    pub delay_p: f64,
    pub delay_ms: u64,
    pub duplicate_p: f64,
    pub reorder_p: f64,
    pub corrupt_p: f64,
    pub reset_p: f64,
    pub refuse_p: f64,
    /// Read-stall (half-open) windows: receivers stop reading.
    pub stalls: Vec<(u64, u64)>,
    /// Heartbeat partition windows.
    pub partitions: Vec<PartitionSpec>,
}

impl FaultSpec {
    pub fn new() -> FaultSpec {
        FaultSpec::default()
    }

    pub fn drop(mut self, p: f64) -> Self {
        self.drop_p = p;
        self
    }

    pub fn delay(mut self, p: f64, ms: u64) -> Self {
        self.delay_p = p;
        self.delay_ms = ms;
        self
    }

    pub fn duplicate(mut self, p: f64) -> Self {
        self.duplicate_p = p;
        self
    }

    pub fn reorder(mut self, p: f64) -> Self {
        self.reorder_p = p;
        self
    }

    pub fn corrupt(mut self, p: f64) -> Self {
        self.corrupt_p = p;
        self
    }

    pub fn reset(mut self, p: f64) -> Self {
        self.reset_p = p;
        self
    }

    pub fn refuse(mut self, p: f64) -> Self {
        self.refuse_p = p;
        self
    }

    /// Receivers stop reading during `[start_ms, start_ms + dur_ms)`.
    pub fn read_stall(mut self, start_ms: u64, dur_ms: u64) -> Self {
        self.stalls.push((start_ms, dur_ms));
        self
    }

    /// Heartbeats between `a` and `b` freeze during the window.
    pub fn partition(
        mut self,
        a: &str,
        b: &str,
        start_ms: u64,
        dur_ms: u64,
    ) -> Self {
        self.partitions.push(PartitionSpec {
            a: a.to_string(),
            b: b.to_string(),
            start_ms,
            dur_ms,
        });
        self
    }
}

// ---------------------------------------------------------------------------
// Plan
// ---------------------------------------------------------------------------

/// One frame-level fault decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFault {
    None,
    /// Lose the frame's first transmission (retry resends it).
    Drop,
    /// Stall the sender this many milliseconds while framing.
    Delay(u64),
    /// Transmit the frame twice back-to-back.
    Duplicate,
    /// Retransmit a stale copy of the previous frame after this one.
    Reorder,
    /// Transmit an extra copy of the frame with the byte at
    /// `salt % span` past the length prefix flipped — guaranteed to
    /// trip the receiver's checksum check and cut the connection.
    Corrupt(u32),
}

impl FrameFault {
    /// Stable short name (labels, logs, schedule dumps).
    pub fn name(&self) -> &'static str {
        match self {
            FrameFault::None => "none",
            FrameFault::Drop => "drop",
            FrameFault::Delay(_) => "delay",
            FrameFault::Duplicate => "duplicate",
            FrameFault::Reorder => "reorder",
            FrameFault::Corrupt(_) => "corrupt",
        }
    }
}

/// Injected-fault tallies, bumped by the hook as faults fire (not as
/// they are scheduled): two runs of the same seed over the same
/// traffic must produce identical snapshots.
#[derive(Debug, Default)]
pub struct FaultCounts {
    pub drops: AtomicU64,
    pub delays: AtomicU64,
    pub duplicates: AtomicU64,
    pub reorders: AtomicU64,
    pub corrupts: AtomicU64,
    pub resets: AtomicU64,
    pub refusals: AtomicU64,
}

/// Point-in-time copy of [`FaultCounts`] (comparable across runs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultCountsSnapshot {
    pub drops: u64,
    pub delays: u64,
    pub duplicates: u64,
    pub reorders: u64,
    pub corrupts: u64,
    pub resets: u64,
    pub refusals: u64,
}

impl FaultCounts {
    pub fn snapshot(&self) -> FaultCountsSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::SeqCst);
        FaultCountsSnapshot {
            drops: ld(&self.drops),
            delays: ld(&self.delays),
            duplicates: ld(&self.duplicates),
            reorders: ld(&self.reorders),
            corrupts: ld(&self.corrupts),
            resets: ld(&self.resets),
            refusals: ld(&self.refusals),
        }
    }

    fn record_frame(&self, f: &FrameFault) {
        let c = match f {
            FrameFault::None => return,
            FrameFault::Drop => &self.drops,
            FrameFault::Delay(_) => &self.delays,
            FrameFault::Duplicate => &self.duplicates,
            FrameFault::Reorder => &self.reorders,
            FrameFault::Corrupt(_) => &self.corrupts,
        };
        c.fetch_add(1, Ordering::SeqCst);
        crate::telemetry::ctr_chaos_injected(f.name()).inc();
    }
}

/// SplitMix64 finalizer — the same mixer [`Rng`] seeds through, kept
/// local so plan derivation is self-contained and stable.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over the link name: folds the textual identity of a sender
/// or listener into the per-decision seed.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Derive the decision stream for `(seed, link, index)` under a
/// per-seam `salt` so sender-frame, sender-reset, and listener-accept
/// decisions never correlate.
fn decision_rng(seed: u64, salt: u64, link: &str, index: u64) -> Rng {
    let mut z = splitmix(seed ^ salt);
    z = splitmix(z ^ fnv64(link));
    z = splitmix(z ^ index);
    Rng::new(z)
}

const SALT_FRAME: u64 = 0xF1A7;
const SALT_RESET: u64 = 0x2E5E;
const SALT_REFUSE: u64 = 0x3EF5;

/// A compiled fault schedule: seed + spec + the arm instant the time
/// windows are measured from.  All per-frame queries are pure — the
/// plan carries no mutable schedule state, only outcome tallies.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
    epoch: Instant,
    /// Tallies of faults actually injected (see [`FaultCounts`]).
    pub counts: FaultCounts,
}

impl FaultPlan {
    pub fn compile(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            seed,
            spec,
            epoch: Instant::now(),
            counts: FaultCounts::default(),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Milliseconds since the plan was armed.
    pub fn elapsed_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// The fault (if any) for frame `index` on `link`.  Pure: depends
    /// only on `(seed, spec, link, index)`.
    pub fn frame_fault(&self, link: &str, index: u64) -> FrameFault {
        let mut rng = decision_rng(self.seed, SALT_FRAME, link, index);
        // Fixed draw order: each probability consumes one draw, so a
        // spec change reshuffles later categories but a fixed spec is
        // byte-stable forever.
        if rng.chance(self.spec.drop_p) {
            return FrameFault::Drop;
        }
        if rng.chance(self.spec.corrupt_p) {
            return FrameFault::Corrupt(rng.next_u64() as u32);
        }
        if rng.chance(self.spec.duplicate_p) {
            return FrameFault::Duplicate;
        }
        if rng.chance(self.spec.reorder_p) {
            return FrameFault::Reorder;
        }
        if rng.chance(self.spec.delay_p) {
            return FrameFault::Delay(self.spec.delay_ms);
        }
        FrameFault::None
    }

    /// Whether the sender's connection resets before batch `index`.
    pub fn reset_at(&self, link: &str, index: u64) -> bool {
        decision_rng(self.seed, SALT_RESET, link, index)
            .chance(self.spec.reset_p)
    }

    /// Whether the listener refuses accepted connection `index`.
    pub fn refuse_at(&self, link: &str, index: u64) -> bool {
        decision_rng(self.seed, SALT_REFUSE, link, index)
            .chance(self.spec.refuse_p)
    }

    /// Whether receivers are read-stalled right now.
    pub fn read_stalled(&self) -> bool {
        let now = self.elapsed_ms();
        self.spec
            .stalls
            .iter()
            .any(|&(s, d)| now >= s && now < s.saturating_add(d))
    }

    /// Whether a partition window between `x` and `y` is active.
    pub fn partition_active(&self, x: &str, y: &str) -> bool {
        let now = self.elapsed_ms();
        self.spec.partitions.iter().any(|p| {
            let side = |a: &str, b: &str| {
                (a == ANY || a == x) && (b == ANY || b == y)
            };
            (side(&p.a, &p.b) || side(&p.b, &p.a))
                && now >= p.start_ms
                && now < p.start_ms.saturating_add(p.dur_ms)
        })
    }

    /// The first `n` frame faults for `link`.
    pub fn schedule(&self, link: &str, n: u64) -> Vec<FrameFault> {
        (0..n).map(|i| self.frame_fault(link, i)).collect()
    }

    /// Byte-serialized schedule (tag + params per frame) — the unit
    /// the determinism properties compare.
    pub fn schedule_bytes(&self, link: &str, n: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(n as usize);
        for f in self.schedule(link, n) {
            match f {
                FrameFault::None => out.push(0),
                FrameFault::Drop => out.push(1),
                FrameFault::Delay(ms) => {
                    out.push(2);
                    out.extend_from_slice(&ms.to_le_bytes());
                }
                FrameFault::Duplicate => out.push(3),
                FrameFault::Reorder => out.push(4),
                FrameFault::Corrupt(salt) => {
                    out.push(5);
                    out.extend_from_slice(&salt.to_le_bytes());
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Process-global hook
// ---------------------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);

fn slot() -> &'static RwLock<Option<Arc<FaultPlan>>> {
    static SLOT: OnceLock<RwLock<Option<Arc<FaultPlan>>>> =
        OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(None))
}

/// Whether a plan is armed.  One relaxed load — the entire hot-path
/// cost of this module when chaos is off.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// The armed plan, if any.
pub fn plan() -> Option<Arc<FaultPlan>> {
    if !armed() {
        return None;
    }
    slot().read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Disarms the plan armed alongside it when dropped, so a panicking
/// test cannot leak faults into the rest of the suite.
pub struct ArmGuard {
    plan: Arc<FaultPlan>,
}

impl ArmGuard {
    /// The armed plan (outcome tallies, schedule queries).
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        disarm();
    }
}

/// Arm `plan` process-wide.  The plan's time windows restart at this
/// instant.  Prints the seed so any failure reproduces by pinning it.
pub fn arm(mut plan: FaultPlan) -> ArmGuard {
    plan.epoch = Instant::now();
    let seed = plan.seed;
    let plan = Arc::new(plan);
    *slot().write().unwrap_or_else(|e| e.into_inner()) =
        Some(Arc::clone(&plan));
    ARMED.store(true, Ordering::SeqCst);
    crate::telemetry::ctr_chaos_arms().inc();
    crate::log_info!("chaos: plan armed (seed {seed:#x})");
    ArmGuard { plan }
}

/// Drop the armed plan; hooks return to their no-op fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    *slot().write().unwrap_or_else(|e| e.into_inner()) = None;
}

// Seam-facing consults.  Each takes the one-relaxed-load early exit
// before touching the plan slot, and tallies the faults it hands out.

pub(crate) fn tx_frame_fault(link: &str, index: u64) -> FrameFault {
    if !armed() {
        return FrameFault::None;
    }
    match plan() {
        Some(p) => {
            let f = p.frame_fault(link, index);
            p.counts.record_frame(&f);
            f
        }
        None => FrameFault::None,
    }
}

pub(crate) fn tx_reset_fault(link: &str, index: u64) -> bool {
    if !armed() {
        return false;
    }
    match plan() {
        Some(p) if p.reset_at(link, index) => {
            p.counts.resets.fetch_add(1, Ordering::SeqCst);
            crate::telemetry::ctr_chaos_injected("reset").inc();
            true
        }
        _ => false,
    }
}

pub(crate) fn rx_refuse_fault(link: &str, index: u64) -> bool {
    if !armed() {
        return false;
    }
    match plan() {
        Some(p) if p.refuse_at(link, index) => {
            p.counts.refusals.fetch_add(1, Ordering::SeqCst);
            crate::telemetry::ctr_chaos_injected("refuse").inc();
            true
        }
        _ => false,
    }
}

pub(crate) fn rx_read_stalled() -> bool {
    if !armed() {
        return false;
    }
    plan().is_some_and(|p| p.read_stalled())
}

pub(crate) fn heartbeat_stalled(container: &str) -> bool {
    if !armed() {
        return false;
    }
    plan().is_some_and(|p| p.partition_active(container, COORDINATOR))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_spec() -> FaultSpec {
        FaultSpec::new()
            .drop(0.1)
            .delay(0.1, 3)
            .duplicate(0.1)
            .reorder(0.1)
            .corrupt(0.1)
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::compile(42, mixed_spec());
        let b = FaultPlan::compile(42, mixed_spec());
        assert_eq!(
            a.schedule_bytes("tcp://x:1/in", 512),
            b.schedule_bytes("tcp://x:1/in", 512)
        );
    }

    #[test]
    fn different_seed_different_schedule() {
        let a = FaultPlan::compile(42, mixed_spec());
        let b = FaultPlan::compile(43, mixed_spec());
        assert_ne!(
            a.schedule_bytes("tcp://x:1/in", 512),
            b.schedule_bytes("tcp://x:1/in", 512)
        );
    }

    #[test]
    fn links_decorrelated() {
        let p = FaultPlan::compile(7, mixed_spec());
        assert_ne!(
            p.schedule_bytes("link-a", 512),
            p.schedule_bytes("link-b", 512)
        );
    }

    #[test]
    fn empty_spec_is_all_none() {
        let p = FaultPlan::compile(9, FaultSpec::new());
        assert!(p
            .schedule("any", 256)
            .iter()
            .all(|f| *f == FrameFault::None));
        assert!(!p.reset_at("any", 0));
        assert!(!p.refuse_at("any", 0));
    }

    #[test]
    fn rates_roughly_match_spec() {
        let p = FaultPlan::compile(1, FaultSpec::new().drop(0.2));
        let n = 4000u64;
        let drops = p
            .schedule("l", n)
            .iter()
            .filter(|f| **f == FrameFault::Drop)
            .count();
        let rate = drops as f64 / n as f64;
        assert!((0.15..0.25).contains(&rate), "rate {rate}");
    }

    #[test]
    fn partition_windows_match_unordered_and_wildcard() {
        let p = FaultPlan::compile(
            0,
            FaultSpec::new()
                .partition("c-1", COORDINATOR, 0, 60_000)
                .partition("c-9", ANY, 0, 60_000),
        );
        assert!(p.partition_active("c-1", COORDINATOR));
        assert!(p.partition_active(COORDINATOR, "c-1"));
        assert!(p.partition_active("c-9", "anything"));
        assert!(!p.partition_active("c-2", COORDINATOR));
    }

    #[test]
    fn windows_respect_start_offset() {
        let p = FaultPlan::compile(
            0,
            FaultSpec::new()
                .partition("c-1", COORDINATOR, 3_600_000, 1_000)
                .read_stall(3_600_000, 1_000),
        );
        assert!(!p.partition_active("c-1", COORDINATOR));
        assert!(!p.read_stalled());
    }

    #[test]
    fn arm_guard_disarms_on_drop() {
        // Serialized against nothing: this is the only in-crate test
        // that arms, and integration suites run in their own process.
        {
            let g = arm(FaultPlan::compile(5, FaultSpec::new()));
            assert!(armed());
            assert_eq!(g.plan().seed(), 5);
        }
        assert!(!armed());
        assert!(plan().is_none());
    }
}
