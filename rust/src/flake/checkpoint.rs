//! Checkpoint/restore of pellet state — the paper's §II-A future-work
//! resilience hook, implemented: "using an explicit state object allows
//! the framework to offer resilience through transparent checkpointing of
//! the state object and resuming from the last saved state and the input
//! messages available then."
//!
//! A checkpoint captures, per flake: the state object (JSON), the logic
//! version, and the messages buffered in the input queues at capture
//! time.  Restore re-seeds a (possibly fresh) flake with both.

use std::collections::BTreeMap;

use super::Flake;
use crate::error::{FloeError, Result};
use crate::message::Message;
use crate::util::json::Json;

/// Serialized snapshot of one flake.
#[derive(Debug, Clone, PartialEq)]
pub struct FlakeCheckpoint {
    pub pellet_id: String,
    pub version: u64,
    /// State object contents.
    pub state: BTreeMap<String, Json>,
    /// Buffered input messages per port (wire-encoded).
    pub queued: BTreeMap<String, Vec<Vec<u8>>>,
    /// Per-port dedup high-water marks (highest message `seq`
    /// dispatched before the capture).  Restoring them lets a
    /// replacement flake with `dedup` enabled drop messages an
    /// at-least-once upstream replays from before the checkpoint.
    pub seen: BTreeMap<String, u64>,
}

impl FlakeCheckpoint {
    /// Serialize to a JSON document (suitable for durable storage).
    pub fn to_json(&self) -> Json {
        let state = Json::Obj(self.state.clone());
        let mut queued = BTreeMap::new();
        for (port, msgs) in &self.queued {
            queued.insert(
                port.clone(),
                Json::Arr(
                    msgs.iter()
                        .map(|m| Json::Str(hex_encode(m)))
                        .collect(),
                ),
            );
        }
        let seen = self
            .seen
            .iter()
            .map(|(p, s)| (p.clone(), Json::num(*s as f64)))
            .collect();
        Json::obj(vec![
            ("pellet_id", Json::str(self.pellet_id.clone())),
            ("version", Json::num(self.version as f64)),
            ("state", state),
            ("queued", Json::Obj(queued)),
            ("seen", Json::Obj(seen)),
        ])
    }

    /// Parse back from the JSON document.
    pub fn from_json(j: &Json) -> Result<FlakeCheckpoint> {
        let pellet_id = j
            .get("pellet_id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| {
                FloeError::Parse("checkpoint: missing pellet_id".into())
            })?
            .to_string();
        let version = j
            .get("version")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0) as u64;
        let state = j
            .get("state")
            .and_then(|v| v.as_obj())
            .cloned()
            .unwrap_or_default();
        let mut queued = BTreeMap::new();
        if let Some(obj) = j.get("queued").and_then(|v| v.as_obj()) {
            for (port, arr) in obj {
                let mut msgs = Vec::new();
                for item in arr.as_arr().unwrap_or(&[]) {
                    let hex = item.as_str().ok_or_else(|| {
                        FloeError::Parse(
                            "checkpoint: non-string message".into(),
                        )
                    })?;
                    msgs.push(hex_decode(hex)?);
                }
                queued.insert(port.clone(), msgs);
            }
        }
        // Absent in pre-dedup documents: default to no watermarks.
        let mut seen = BTreeMap::new();
        if let Some(obj) = j.get("seen").and_then(|v| v.as_obj()) {
            for (port, mark) in obj {
                seen.insert(
                    port.clone(),
                    mark.as_f64().unwrap_or(0.0) as u64,
                );
            }
        }
        Ok(FlakeCheckpoint { pellet_id, version, state, queued, seen })
    }
}

fn hex_encode(b: &[u8]) -> String {
    let mut s = String::with_capacity(b.len() * 2);
    for byte in b {
        s.push_str(&format!("{byte:02x}"));
    }
    s
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(FloeError::Parse("checkpoint: odd hex length".into()));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16).map_err(|_| {
                FloeError::Parse("checkpoint: invalid hex".into())
            })
        })
        .collect()
}

impl Flake {
    /// Capture a checkpoint.  Pauses intake, drains in-flight compute,
    /// snapshots state + queued messages, resumes.  The queued messages
    /// remain in the queue (non-destructive capture).
    pub fn checkpoint(&self) -> Result<FlakeCheckpoint> {
        self.pause();
        // Wait for in-flight work so the state snapshot is consistent
        // with the queue contents.
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(30);
        while self
            .probes()
            .inflight
            .load(std::sync::atomic::Ordering::SeqCst)
            > 0
            || self.ready_len() > 0
        {
            if self
                .shared
                .stop
                .load(std::sync::atomic::Ordering::SeqCst)
            {
                // The flake is shutting down (or was killed) under us;
                // abort instead of spinning out the full drain window.
                self.resume();
                return Err(FloeError::Pellet(
                    "checkpoint: flake stopped".into(),
                ));
            }
            if std::time::Instant::now() > deadline {
                self.resume();
                return Err(FloeError::Pellet(
                    "checkpoint: drain timed out".into(),
                ));
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let mut queued = BTreeMap::new();
        for port in self.input_ports() {
            let q = self.input_queue(&port)?;
            // Non-destructive capture: the sharded queue snapshots its
            // buffered messages in place (per-shard FIFO order), so
            // nothing is popped and capacity never blocks the capture.
            let encoded: Vec<Vec<u8>> =
                q.snapshot().iter().map(Message::encode).collect();
            queued.insert(port, encoded);
        }
        let cp = FlakeCheckpoint {
            pellet_id: self.pellet_id().to_string(),
            version: self.version(),
            state: self.state().snapshot(),
            queued,
            seen: self.dedup_watermarks(),
        };
        self.resume();
        Ok(cp)
    }

    /// Capture a **handoff** checkpoint for flake relocation: pause
    /// intake, interrupt and drain in-flight compute, then
    /// *destructively* take the buffered input queues
    /// ([`crate::channel::ShardedQueue::drain_all`]) so the buffered
    /// stream can be rebound to a replacement flake with no
    /// double-processing.  The flake stays paused afterwards — the
    /// caller restores the checkpoint into the replacement and tears
    /// this flake down.  Only sound once upstream producers are
    /// quiesced or rewired; the recomposition engine guarantees both.
    pub fn handoff(&self) -> Result<FlakeCheckpoint> {
        self.quiesce(std::time::Duration::from_secs(30))?;
        let mut queued = BTreeMap::new();
        for port in self.input_ports() {
            let q = self.input_queue(&port)?;
            // Close *before* the capture: a racing producer either
            // lands before the close (and is captured below) or gets
            // an error and re-resolves the replacement — a message can
            // never strand in a husk about to be torn down.
            q.close();
            let encoded: Vec<Vec<u8>> =
                q.drain_all().iter().map(Message::encode).collect();
            queued.insert(port, encoded);
        }
        Ok(FlakeCheckpoint {
            pellet_id: self.pellet_id().to_string(),
            version: self.version(),
            state: self.state().snapshot(),
            queued,
            seen: self.dedup_watermarks(),
        })
    }

    /// Restore a checkpoint into this flake: state object contents are
    /// replaced and queued messages re-injected (used when resuming a
    /// pellet on a fresh flake after failure).
    ///
    /// Replay happens from the calling thread, which pins one shard per
    /// input port, so keep the flake running (not paused) during
    /// restore: the dispatcher drains the shard as it fills, letting
    /// replays larger than the per-shard bound
    /// (`queue_capacity / input_shards`) complete under backpressure.
    pub fn restore(&self, cp: &FlakeCheckpoint) -> Result<()> {
        if cp.pellet_id != self.pellet_id() {
            return Err(FloeError::Pellet(format!(
                "restore: checkpoint is for '{}', flake is '{}'",
                cp.pellet_id,
                self.pellet_id()
            )));
        }
        for (k, v) in &cp.state {
            self.state().set(k, v.clone());
        }
        // Watermarks first: the replayed queue contents below all sit
        // above them (they had not been dispatched at capture time),
        // while anything an at-least-once upstream re-sends from
        // before the capture now gets dropped at the dispatcher.
        self.set_dedup_watermarks(&cp.seen);
        for (port, msgs) in &cp.queued {
            for bytes in msgs {
                self.inject(port, Message::decode(bytes)?)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flake::FlakeConfig;
    use crate::graph::{
        InPortSpec, MergeMode, OutPortSpec, SplitMode, TriggerMode,
        WindowSpec,
    };
    use std::sync::Arc;

    fn test_flake(id: &str) -> Arc<Flake> {
        let cfg = FlakeConfig {
            pellet_id: id.into(),
            class: "floe.builtin.CountSink".into(),
            inputs: vec![InPortSpec {
                name: "in".into(),
                window: WindowSpec::None,
            }],
            outputs: vec![OutPortSpec {
                name: "out".into(),
                split: SplitMode::RoundRobin,
            }],
            merge: MergeMode::Interleaved,
            trigger: TriggerMode::Push,
            sequential: false,
            stateful: true,
            cores: 1,
            alpha: 2,
            queue_capacity: 256,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: 2,
            channel_backend: crate::channel::ChannelBackend::default(),
            dedup: false,
        };
        Flake::start(
            cfg,
            Arc::new(|| Box::new(crate::pellet::builtins::CountSink)),
        )
    }

    #[test]
    fn checkpoint_roundtrips_through_json() {
        let flake = test_flake("cp");
        for i in 0..10 {
            flake.inject("in", Message::text(format!("{i}"))).unwrap();
        }
        flake.drain(std::time::Duration::from_secs(5));
        let cp = flake.checkpoint().unwrap();
        assert_eq!(cp.pellet_id, "cp");
        assert_eq!(cp.state.get("count"), Some(&Json::Num(10.0)));
        let j = cp.to_json();
        let back = FlakeCheckpoint::from_json(&j).unwrap();
        assert_eq!(cp, back);
        flake.shutdown();
    }

    #[test]
    fn checkpoint_captures_queued_messages() {
        let flake = test_flake("cpq");
        flake.pause(); // hold intake so messages stay queued
        for i in 0..5 {
            flake.inject("in", Message::text(format!("q{i}"))).unwrap();
        }
        let cp = flake.checkpoint().unwrap();
        assert_eq!(cp.queued["in"].len(), 5);
        // Non-destructive: the flake still processes them after resume.
        assert!(flake.drain(std::time::Duration::from_secs(5)));
        assert_eq!(
            flake.state().get("count"),
            Some(Json::Num(5.0))
        );
        flake.shutdown();
    }

    #[test]
    fn restore_into_fresh_flake_resumes_processing() {
        // Original flake: 7 processed, 3 still queued at checkpoint time.
        let original = test_flake("worker");
        for i in 0..7 {
            original.inject("in", Message::text(format!("{i}"))).unwrap();
        }
        original.drain(std::time::Duration::from_secs(5));
        original.pause();
        for i in 7..10 {
            original.inject("in", Message::text(format!("{i}"))).unwrap();
        }
        let cp = original.checkpoint().unwrap();
        original.shutdown(); // "failure"

        // Fresh replacement resumes from the snapshot.
        let replacement = test_flake("worker");
        replacement.restore(&cp).unwrap();
        assert!(replacement.drain(std::time::Duration::from_secs(5)));
        assert_eq!(
            replacement.state().get("count"),
            Some(Json::Num(10.0)) // 7 from state + 3 replayed messages
        );
        replacement.shutdown();
    }

    #[test]
    fn handoff_is_destructive_and_leaves_paused() {
        let original = test_flake("move");
        original.pause();
        for i in 0..6 {
            original.inject("in", Message::text(format!("{i}"))).unwrap();
        }
        let cp = original.handoff().unwrap();
        assert_eq!(cp.queued["in"].len(), 6);
        // Destructive: the source queue is empty and the flake paused,
        // so nothing is processed twice after the stream moves.
        assert_eq!(original.queue_len(), 0);
        assert!(original.is_paused());
        // Late producers hit a closed queue instead of losing data.
        assert!(original.inject("in", Message::text("late")).is_err());
        original.shutdown();

        let replacement = test_flake("move");
        replacement.restore(&cp).unwrap();
        assert!(replacement.drain(std::time::Duration::from_secs(5)));
        assert_eq!(replacement.state().get("count"), Some(Json::Num(6.0)));
        replacement.shutdown();
    }

    #[test]
    fn restore_rejects_wrong_pellet() {
        let a = test_flake("a");
        let b = test_flake("b");
        let cp = a.checkpoint().unwrap();
        assert!(b.restore(&cp).is_err());
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn hex_roundtrip() {
        for bytes in [vec![], vec![0u8], vec![0xde, 0xad, 0xbe, 0xef]] {
            assert_eq!(hex_decode(&hex_encode(&bytes)).unwrap(), bytes);
        }
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }
}
