//! Output routing: applies the split annotation of each output port
//! (duplicate / round-robin / key-hash, Fig. 1 P7–P9) to pick the outgoing
//! edge(s) for every emitted message.
//!
//! Landmark control messages are always broadcast to *every* edge of the
//! port regardless of split mode — a WindowEnd or Update landmark must
//! reach all downstream reducers/pellets to be meaningful.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::channel::Transport;
use crate::error::{FloeError, Result};
use crate::graph::SplitMode;
use crate::message::{key_hash, Message};

struct PortRoutes {
    split: SplitMode,
    targets: Vec<Arc<dyn Transport>>,
    rr: AtomicUsize,
}

/// Per-flake output router.
pub struct OutputRouter {
    ports: HashMap<String, PortRoutes>,
    /// Messages routed (for probes).
    pub routed: AtomicUsize,
    /// Messages emitted on ports with no outgoing edges (sinks) — dropped.
    pub dropped: AtomicUsize,
}

impl OutputRouter {
    pub fn new() -> OutputRouter {
        OutputRouter {
            ports: HashMap::new(),
            routed: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    /// Declare an output port with its split mode.
    pub fn add_port(&mut self, name: &str, split: SplitMode) {
        self.ports.insert(
            name.to_string(),
            PortRoutes { split, targets: Vec::new(), rr: AtomicUsize::new(0) },
        );
    }

    /// Wire one outgoing edge (coordinator does this bottom-up).
    pub fn add_target(
        &mut self,
        port: &str,
        transport: Arc<dyn Transport>,
    ) -> Result<()> {
        self.ports
            .get_mut(port)
            .ok_or_else(|| {
                FloeError::Graph(format!("router: unknown out port '{port}'"))
            })?
            .targets
            .push(transport);
        Ok(())
    }

    pub fn has_port(&self, port: &str) -> bool {
        self.ports.contains_key(port)
    }

    pub fn target_count(&self, port: &str) -> usize {
        self.ports.get(port).map(|p| p.targets.len()).unwrap_or(0)
    }

    /// Route one message according to the port's split annotation.
    pub fn route(&self, port: &str, msg: Message) -> Result<()> {
        let routes = self.ports.get(port).ok_or_else(|| {
            FloeError::Channel(format!("router: no out port '{port}'"))
        })?;
        if routes.targets.is_empty() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.routed.fetch_add(1, Ordering::Relaxed);
        if msg.is_landmark() {
            // Control messages reach every downstream pellet.
            for t in &routes.targets {
                t.send(msg.clone())?;
            }
            return Ok(());
        }
        match routes.split {
            SplitMode::Duplicate => {
                for t in &routes.targets {
                    t.send(msg.clone())?;
                }
            }
            SplitMode::RoundRobin => {
                let i = routes.rr.fetch_add(1, Ordering::Relaxed)
                    % routes.targets.len();
                routes.targets[i].send(msg)?;
            }
            SplitMode::KeyHash => {
                // Hash the explicit key; fall back to text payload so
                // un-keyed messages still route deterministically.
                let key = msg
                    .key
                    .as_deref()
                    .or_else(|| msg.as_text())
                    .unwrap_or("");
                let i =
                    (key_hash(key) % routes.targets.len() as u64) as usize;
                routes.targets[i].send(msg)?;
            }
        }
        Ok(())
    }
}

impl Default for OutputRouter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{InProcTransport, SyncQueue};
    use crate::message::Landmark;

    fn sink() -> (Arc<SyncQueue<Message>>, Arc<dyn Transport>) {
        let q = Arc::new(SyncQueue::new(1024));
        let t: Arc<dyn Transport> = Arc::new(InProcTransport {
            queue: Arc::clone(&q),
            label: "t".into(),
        });
        (q, t)
    }

    fn router_with(
        split: SplitMode,
        n: usize,
    ) -> (OutputRouter, Vec<Arc<SyncQueue<Message>>>) {
        let mut r = OutputRouter::new();
        r.add_port("out", split);
        let mut queues = Vec::new();
        for _ in 0..n {
            let (q, t) = sink();
            r.add_target("out", t).unwrap();
            queues.push(q);
        }
        (r, queues)
    }

    #[test]
    fn duplicate_copies_to_all() {
        let (r, qs) = router_with(SplitMode::Duplicate, 3);
        r.route("out", Message::text("x")).unwrap();
        for q in &qs {
            assert_eq!(q.len(), 1);
        }
    }

    #[test]
    fn round_robin_balances() {
        let (r, qs) = router_with(SplitMode::RoundRobin, 3);
        for i in 0..9 {
            r.route("out", Message::text(format!("{i}"))).unwrap();
        }
        for q in &qs {
            assert_eq!(q.len(), 3);
        }
        // Order preserved per target.
        assert_eq!(qs[0].pop().unwrap().as_text(), Some("0"));
        assert_eq!(qs[0].pop().unwrap().as_text(), Some("3"));
    }

    #[test]
    fn key_hash_groups_keys() {
        let (r, qs) = router_with(SplitMode::KeyHash, 4);
        for i in 0..100 {
            let key = format!("key-{}", i % 10);
            r.route("out", Message::text("v").with_key(&key)).unwrap();
        }
        // Re-route the same keys: distribution must be identical, i.e. all
        // messages with one key land in one queue.
        let total: usize = qs.iter().map(|q| q.len()).sum();
        assert_eq!(total, 100);
        // Each of the 10 keys maps to exactly one queue; with 10 keys over
        // 4 queues each queue holds a multiple of 10.
        for q in &qs {
            assert_eq!(q.len() % 10, 0, "len={}", q.len());
        }
    }

    #[test]
    fn keyhash_falls_back_to_text() {
        let (r, qs) = router_with(SplitMode::KeyHash, 2);
        r.route("out", Message::text("same")).unwrap();
        r.route("out", Message::text("same")).unwrap();
        let lens: Vec<usize> = qs.iter().map(|q| q.len()).collect();
        assert!(lens.contains(&2), "{lens:?}"); // same text -> same target
    }

    #[test]
    fn landmarks_broadcast_on_any_split() {
        for split in
            [SplitMode::RoundRobin, SplitMode::KeyHash, SplitMode::Duplicate]
        {
            let (r, qs) = router_with(split, 3);
            r.route(
                "out",
                Message::landmark(Landmark::WindowEnd("w".into())),
            )
            .unwrap();
            for q in &qs {
                assert_eq!(q.len(), 1, "split {split:?}");
            }
        }
    }

    #[test]
    fn sink_port_drops_and_counts() {
        let mut r = OutputRouter::new();
        r.add_port("out", SplitMode::RoundRobin);
        r.route("out", Message::text("gone")).unwrap();
        assert_eq!(r.dropped.load(Ordering::Relaxed), 1);
        assert!(r.route("missing", Message::text("x")).is_err());
    }
}
