//! Output routing: applies the split annotation of each output port
//! (duplicate / round-robin / key-hash, Fig. 1 P7–P9) to pick the outgoing
//! edge(s) for every emitted message.
//!
//! Landmark control messages are always broadcast to *every* edge of the
//! port regardless of split mode — a WindowEnd or Update landmark must
//! reach all downstream reducers/pellets to be meaningful.
//!
//! Targets are [`Transport`] handles; on a coordinator-launched
//! dataflow they are **logical endpoint handles**
//! ([`crate::channel::EndpointTransport`]) that resolve the sink's
//! `floe://<flake>/<port>` address through the versioned endpoint
//! table per send — so routing survives a sink relocation without the
//! router being rewired.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::channel::Transport;
use crate::error::{FloeError, Result};
use crate::graph::SplitMode;
use crate::message::Message;

struct PortRoutes {
    split: SplitMode,
    targets: Vec<Arc<dyn Transport>>,
    rr: AtomicUsize,
}

/// Per-flake output router.
pub struct OutputRouter {
    ports: HashMap<String, PortRoutes>,
    /// Messages routed (for probes).
    pub routed: AtomicUsize,
    /// Messages emitted on ports with no outgoing edges (sinks) — dropped.
    pub dropped: AtomicUsize,
}

impl OutputRouter {
    pub fn new() -> OutputRouter {
        OutputRouter {
            ports: HashMap::new(),
            routed: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    /// Declare an output port with its split mode.
    pub fn add_port(&mut self, name: &str, split: SplitMode) {
        self.ports.insert(
            name.to_string(),
            PortRoutes { split, targets: Vec::new(), rr: AtomicUsize::new(0) },
        );
    }

    /// Wire one outgoing edge (coordinator does this bottom-up).
    pub fn add_target(
        &mut self,
        port: &str,
        transport: Arc<dyn Transport>,
    ) -> Result<()> {
        self.ports
            .get_mut(port)
            .ok_or_else(|| {
                FloeError::Graph(format!("router: unknown out port '{port}'"))
            })?
            .targets
            .push(transport);
        Ok(())
    }

    /// Drop every outgoing edge of a port (graph surgery).  The port
    /// itself stays declared; subsequent emissions are counted as
    /// drops until new targets are wired.
    pub fn clear_targets(&mut self, port: &str) -> Result<()> {
        let routes = self.ports.get_mut(port).ok_or_else(|| {
            FloeError::Graph(format!("router: unknown out port '{port}'"))
        })?;
        routes.targets.clear();
        routes.rr.store(0, Ordering::Relaxed);
        Ok(())
    }

    /// Atomically replace a port's outgoing edges with a new target
    /// set.  Callers hold the flake's router write lock for the whole
    /// swap, so routing threads observe either the old wiring or the
    /// new one, never a mix — the cut-over primitive of
    /// [`crate::recompose`].
    pub fn replace_targets(
        &mut self,
        port: &str,
        targets: Vec<Arc<dyn Transport>>,
    ) -> Result<()> {
        let routes = self.ports.get_mut(port).ok_or_else(|| {
            FloeError::Graph(format!("router: unknown out port '{port}'"))
        })?;
        routes.targets = targets;
        routes.rr.store(0, Ordering::Relaxed);
        Ok(())
    }

    pub fn has_port(&self, port: &str) -> bool {
        self.ports.contains_key(port)
    }

    /// Names of the declared output ports.
    pub fn port_names(&self) -> Vec<String> {
        self.ports.keys().cloned().collect()
    }

    pub fn target_count(&self, port: &str) -> usize {
        self.ports.get(port).map(|p| p.targets.len()).unwrap_or(0)
    }

    /// Route a whole batch of messages according to the port's split
    /// annotation, delivering one [`Transport::send_batch`] per target
    /// instead of one `send` per message.  Per-target message order
    /// matches what repeated [`OutputRouter::route`] calls would produce
    /// (the round-robin counter advances once per data message, landmarks
    /// broadcast to every edge).
    pub fn route_batch(
        &self,
        port: &str,
        msgs: Vec<Message>,
    ) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        let routes = self.ports.get(port).ok_or_else(|| {
            FloeError::Channel(format!("router: no out port '{port}'"))
        })?;
        if routes.targets.is_empty() {
            self.dropped.fetch_add(msgs.len(), Ordering::Relaxed);
            return Ok(());
        }
        self.routed.fetch_add(msgs.len(), Ordering::Relaxed);
        let nt = routes.targets.len();
        if nt == 1 {
            if routes.split == SplitMode::RoundRobin {
                // Keep the counter in step with what repeated route()
                // calls would leave behind (targets can be added later).
                let data = msgs.iter().filter(|m| !m.is_landmark()).count();
                routes.rr.fetch_add(data, Ordering::Relaxed);
            }
            return routes.targets[0].send_batch(msgs);
        }
        let mut per: Vec<Vec<Message>> = (0..nt).map(|_| Vec::new()).collect();
        for msg in msgs {
            if msg.is_landmark() || routes.split == SplitMode::Duplicate {
                // Fan-out shares the Arc-backed envelope: each clone
                // bumps payload/key refcounts (no byte copies), and the
                // last target takes the original by move.
                for batch in per.iter_mut().take(nt - 1) {
                    batch.push(msg.clone());
                }
                per[nt - 1].push(msg);
                continue;
            }
            let i = match routes.split {
                SplitMode::RoundRobin => {
                    routes.rr.fetch_add(1, Ordering::Relaxed) % nt
                }
                // The per-message hash is computed once and cached in
                // the envelope, so repeated key-hash hops stop
                // re-hashing the string (same key/text/"" derivation
                // as always — see `Message::route_hash`).
                SplitMode::KeyHash => {
                    (msg.route_hash() % nt as u64) as usize
                }
                SplitMode::Duplicate => unreachable!("handled above"),
            };
            per[i].push(msg);
        }
        // Deliver to every target even if one fails (e.g. a sink shut
        // down first during teardown): a dead edge must not starve the
        // healthy ones.  The first error is reported after delivery.
        let mut first_err = None;
        for (i, batch) in per.into_iter().enumerate() {
            if !batch.is_empty() {
                if let Err(e) = routes.targets[i].send_batch(batch) {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Route one message according to the port's split annotation.
    /// Delegates to [`OutputRouter::route_batch`], so the split,
    /// landmark-broadcast and deliver-to-all-despite-errors semantics
    /// are identical on both paths.
    pub fn route(&self, port: &str, msg: Message) -> Result<()> {
        self.route_batch(port, vec![msg])
    }

    /// Best-effort **non-blocking** broadcast to every edge of a port,
    /// regardless of split mode.  Control messages (recompose cut
    /// landmarks) use this: a full queue on a paused sibling must
    /// drop the marker rather than wedge the caller.  Returns how
    /// many edges accepted the message; a closed edge reports the
    /// first error after every edge was tried.
    pub fn try_broadcast(&self, port: &str, msg: Message) -> Result<usize> {
        let routes = self.ports.get(port).ok_or_else(|| {
            FloeError::Channel(format!("router: no out port '{port}'"))
        })?;
        let mut delivered = 0;
        let mut first_err = None;
        for t in &routes.targets {
            match t.try_send(msg.clone()) {
                Ok(true) => delivered += 1,
                Ok(false) => {}
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(delivered),
            Some(e) => Err(e),
        }
    }
}

impl Default for OutputRouter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{InProcTransport, ShardedQueue};
    use crate::message::Landmark;

    fn sink() -> (Arc<ShardedQueue<Message>>, Arc<dyn Transport>) {
        let q = Arc::new(ShardedQueue::with_default_shards(1024));
        let t: Arc<dyn Transport> = Arc::new(InProcTransport {
            queue: Arc::clone(&q),
            label: "t".into(),
        });
        (q, t)
    }

    fn router_with(
        split: SplitMode,
        n: usize,
    ) -> (OutputRouter, Vec<Arc<ShardedQueue<Message>>>) {
        let mut r = OutputRouter::new();
        r.add_port("out", split);
        let mut queues = Vec::new();
        for _ in 0..n {
            let (q, t) = sink();
            r.add_target("out", t).unwrap();
            queues.push(q);
        }
        (r, queues)
    }

    #[test]
    fn duplicate_copies_to_all() {
        let (r, qs) = router_with(SplitMode::Duplicate, 3);
        r.route("out", Message::text("x")).unwrap();
        for q in &qs {
            assert_eq!(q.len(), 1);
        }
    }

    #[test]
    fn round_robin_balances() {
        let (r, qs) = router_with(SplitMode::RoundRobin, 3);
        for i in 0..9 {
            r.route("out", Message::text(format!("{i}"))).unwrap();
        }
        for q in &qs {
            assert_eq!(q.len(), 3);
        }
        // Order preserved per target.
        assert_eq!(qs[0].pop().unwrap().as_text(), Some("0"));
        assert_eq!(qs[0].pop().unwrap().as_text(), Some("3"));
    }

    #[test]
    fn key_hash_groups_keys() {
        let (r, qs) = router_with(SplitMode::KeyHash, 4);
        for i in 0..100 {
            let key = format!("key-{}", i % 10);
            r.route("out", Message::text("v").with_key(key.as_str()))
                .unwrap();
        }
        // Re-route the same keys: distribution must be identical, i.e. all
        // messages with one key land in one queue.
        let total: usize = qs.iter().map(|q| q.len()).sum();
        assert_eq!(total, 100);
        // Each of the 10 keys maps to exactly one queue; with 10 keys over
        // 4 queues each queue holds a multiple of 10.
        for q in &qs {
            assert_eq!(q.len() % 10, 0, "len={}", q.len());
        }
    }

    #[test]
    fn keyhash_falls_back_to_text() {
        let (r, qs) = router_with(SplitMode::KeyHash, 2);
        r.route("out", Message::text("same")).unwrap();
        r.route("out", Message::text("same")).unwrap();
        let lens: Vec<usize> = qs.iter().map(|q| q.len()).collect();
        assert!(lens.contains(&2), "{lens:?}"); // same text -> same target
    }

    #[test]
    fn landmarks_broadcast_on_any_split() {
        for split in
            [SplitMode::RoundRobin, SplitMode::KeyHash, SplitMode::Duplicate]
        {
            let (r, qs) = router_with(split, 3);
            r.route(
                "out",
                Message::landmark(Landmark::WindowEnd("w".into())),
            )
            .unwrap();
            for q in &qs {
                assert_eq!(q.len(), 1, "split {split:?}");
            }
        }
    }

    #[test]
    fn route_batch_round_robin_matches_single_path() {
        let (rb, qb) = router_with(SplitMode::RoundRobin, 3);
        let (rs, qs) = router_with(SplitMode::RoundRobin, 3);
        let msgs: Vec<Message> =
            (0..9).map(|i| Message::text(format!("{i}"))).collect();
        rb.route_batch("out", msgs.clone()).unwrap();
        for m in msgs {
            rs.route("out", m).unwrap();
        }
        for (b, s) in qb.iter().zip(qs.iter()) {
            assert_eq!(b.len(), 3);
            while let Some(want) = s.try_pop() {
                let got = b.try_pop().unwrap();
                assert_eq!(got.as_text(), want.as_text());
            }
        }
    }

    #[test]
    fn route_batch_keyhash_groups_keys() {
        let (r, qs) = router_with(SplitMode::KeyHash, 4);
        let msgs: Vec<Message> = (0..100)
            .map(|i| Message::text("v").with_key(format!("key-{}", i % 10)))
            .collect();
        r.route_batch("out", msgs).unwrap();
        let total: usize = qs.iter().map(|q| q.len()).sum();
        assert_eq!(total, 100);
        for q in &qs {
            assert_eq!(q.len() % 10, 0, "len={}", q.len());
        }
    }

    #[test]
    fn route_batch_broadcasts_landmarks_and_duplicates() {
        let (r, qs) = router_with(SplitMode::RoundRobin, 3);
        r.route_batch(
            "out",
            vec![
                Message::text("a"),
                Message::landmark(Landmark::WindowEnd("w".into())),
                Message::text("b"),
            ],
        )
        .unwrap();
        // Every sink sees the landmark; the two data messages round-robin.
        let lens: Vec<usize> = qs.iter().map(|q| q.len()).collect();
        assert_eq!(lens.iter().sum::<usize>(), 3 + 2);
        for q in &qs {
            assert!(q.len() >= 1, "{lens:?}");
        }
        let (r2, qs2) = router_with(SplitMode::Duplicate, 2);
        r2.route_batch(
            "out",
            vec![Message::text("x"), Message::text("y")],
        )
        .unwrap();
        for q in &qs2 {
            assert_eq!(q.len(), 2);
        }
    }

    #[test]
    fn route_batch_on_sink_port_counts_drops() {
        let mut r = OutputRouter::new();
        r.add_port("out", SplitMode::RoundRobin);
        r.route_batch(
            "out",
            vec![Message::text("a"), Message::text("b")],
        )
        .unwrap();
        assert_eq!(r.dropped.load(Ordering::Relaxed), 2);
        assert!(r
            .route_batch("missing", vec![Message::text("x")])
            .is_err());
    }

    #[test]
    fn replace_targets_swaps_wiring() {
        let (mut r, qs) = router_with(SplitMode::RoundRobin, 2);
        r.route("out", Message::text("old")).unwrap();
        assert_eq!(qs[0].len() + qs[1].len(), 1);
        let (nq, nt) = sink();
        r.replace_targets("out", vec![nt]).unwrap();
        r.route("out", Message::text("new")).unwrap();
        assert_eq!(nq.len(), 1);
        assert_eq!(qs[0].len() + qs[1].len(), 1, "old targets hit");
        r.clear_targets("out").unwrap();
        r.route("out", Message::text("dropped")).unwrap();
        assert_eq!(r.dropped.load(Ordering::Relaxed), 1);
        assert!(r.replace_targets("ghost", vec![]).is_err());
        assert!(r.clear_targets("ghost").is_err());
    }

    #[test]
    fn sink_port_drops_and_counts() {
        let mut r = OutputRouter::new();
        r.add_port("out", SplitMode::RoundRobin);
        r.route("out", Message::text("gone")).unwrap();
        assert_eq!(r.dropped.load(Ordering::Relaxed), 1);
        assert!(r.route("missing", Message::text("x")).is_err());
    }
}
