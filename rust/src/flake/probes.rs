//! Flake instrumentation (§III: "instrumentation present within flakes for
//! monitoring their queue lengths and average message latencies") — the
//! observations that drive the resource-adaptation strategies.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Hard bound on the rate-estimation window.  `sample_rates` prunes to
/// this on every call, so probe memory stays flat even when no monitor
/// ever drains observations from a long-running flake.
pub const WINDOW_CAP: usize = 5;

/// Lock-free counters plus a small locked window for rate estimation.
pub struct Probes {
    /// Messages that arrived on any input port.
    pub arrivals: AtomicU64,
    /// Messages fully processed by a pellet instance.
    pub completions: AtomicU64,
    /// Messages emitted on output ports.
    pub emissions: AtomicU64,
    /// Work items currently being computed.
    pub inflight: AtomicUsize,
    /// Cumulative busy nanoseconds across instances.
    pub busy_nanos: AtomicU64,
    /// EMA of per-message service latency, nanoseconds (α = 0.2).
    latency_ema_nanos: AtomicU64,
    /// (t, arrivals, completions) snapshots for instantaneous rates.
    window: Mutex<Vec<(f64, u64, u64)>>,
}

/// A point-in-time view handed to adaptation strategies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlakeObservation {
    /// Messages waiting in input queues.
    pub queue_len: usize,
    /// Instantaneous arrival rate (msg/s) over the sampling window.
    pub arrival_rate: f64,
    /// Instantaneous completion rate (msg/s) over the sampling window.
    pub completion_rate: f64,
    /// EMA service latency per message, seconds.
    pub service_latency: f64,
    /// Output/input selectivity observed so far.
    pub selectivity: f64,
    /// Currently allocated cores.
    pub cores: usize,
    /// Currently running instances.
    pub instances: usize,
}

impl Probes {
    pub fn new() -> Probes {
        Probes {
            arrivals: AtomicU64::new(0),
            completions: AtomicU64::new(0),
            emissions: AtomicU64::new(0),
            inflight: AtomicUsize::new(0),
            busy_nanos: AtomicU64::new(0),
            latency_ema_nanos: AtomicU64::new(0),
            window: Mutex::new(Vec::new()),
        }
    }

    pub fn record_arrival(&self, n: u64) {
        self.arrivals.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a completed work item covering `msgs` messages that took
    /// `nanos` to compute.
    pub fn record_completion(&self, msgs: u64, nanos: u64) {
        self.completions.fetch_add(msgs, Ordering::Relaxed);
        self.busy_nanos.fetch_add(nanos, Ordering::Relaxed);
        if msgs > 0 {
            let per_msg = nanos / msgs;
            // EMA with α=0.2 in fixed point.
            let prev = self.latency_ema_nanos.load(Ordering::Relaxed);
            let next = if prev == 0 {
                per_msg
            } else {
                (prev * 4 + per_msg) / 5
            };
            self.latency_ema_nanos.store(next, Ordering::Relaxed);
        }
    }

    pub fn record_emission(&self, n: u64) {
        self.emissions.fetch_add(n, Ordering::Relaxed);
    }

    /// EMA service latency in seconds.
    pub fn latency_secs(&self) -> f64 {
        self.latency_ema_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Observed selectivity (emissions per completion); 1.0 before data.
    pub fn selectivity(&self) -> f64 {
        let c = self.completions.load(Ordering::Relaxed);
        if c == 0 {
            return 1.0;
        }
        self.emissions.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Take a rate sample at time `t` (seconds) and return
    /// (arrival_rate, completion_rate) over the last window (up to
    /// [`WINDOW_CAP`] samples retained).
    pub fn sample_rates(&self, t: f64) -> (f64, f64) {
        let a = self.arrivals.load(Ordering::Relaxed);
        let c = self.completions.load(Ordering::Relaxed);
        let mut w = self.window.lock().expect("probe window poisoned");
        w.push((t, a, c));
        if w.len() > WINDOW_CAP {
            let drop = w.len() - WINDOW_CAP;
            w.drain(..drop);
        }
        if w.len() < 2 {
            return (0.0, 0.0);
        }
        let (t0, a0, c0) = w[0];
        let dt = t - t0;
        if dt <= 0.0 {
            return (0.0, 0.0);
        }
        (
            (a.saturating_sub(a0)) as f64 / dt,
            (c.saturating_sub(c0)) as f64 / dt,
        )
    }

    /// Build a strategy observation.
    pub fn observe(
        &self,
        t: f64,
        queue_len: usize,
        cores: usize,
        instances: usize,
    ) -> FlakeObservation {
        let (arrival_rate, completion_rate) = self.sample_rates(t);
        FlakeObservation {
            queue_len,
            arrival_rate,
            completion_rate,
            service_latency: self.latency_secs(),
            selectivity: self.selectivity(),
            cores,
            instances,
        }
    }
}

impl Default for Probes {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_ema_converges() {
        let p = Probes::new();
        for _ in 0..50 {
            p.record_completion(1, 1_000_000); // 1ms
        }
        let l = p.latency_secs();
        assert!((l - 0.001).abs() < 0.0005, "latency {l}");
    }

    #[test]
    fn selectivity_ratio() {
        let p = Probes::new();
        assert_eq!(p.selectivity(), 1.0);
        p.record_completion(10, 1000);
        p.record_emission(25);
        assert!((p.selectivity() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn rates_from_window() {
        let p = Probes::new();
        p.record_arrival(0);
        let _ = p.sample_rates(0.0);
        p.record_arrival(100);
        p.record_completion(50, 1000);
        let (ar, cr) = p.sample_rates(1.0);
        assert!((ar - 100.0).abs() < 1e-6, "{ar}");
        assert!((cr - 50.0).abs() < 1e-6, "{cr}");
        // Window slides: very old samples dropped after 5.
        for i in 2..10 {
            let _ = p.sample_rates(i as f64);
        }
        let w = p.window.lock().unwrap();
        assert!(w.len() <= WINDOW_CAP);
    }

    #[test]
    fn probe_window_memory_stays_flat() {
        // Regression: a monitor-less long-running flake must not grow
        // the sample window without bound — both length and backing
        // capacity stay pinned near WINDOW_CAP forever.
        let p = Probes::new();
        for i in 0..10_000u32 {
            p.record_arrival(1);
            p.record_completion(1, 500);
            let _ = p.sample_rates(f64::from(i) * 0.01);
        }
        let w = p.window.lock().unwrap();
        assert!(w.len() <= WINDOW_CAP, "window len {} grew", w.len());
        // len never exceeds WINDOW_CAP + 1, so Vec doubling can never
        // push the allocation past a small constant.
        assert!(
            w.capacity() <= 2 * (WINDOW_CAP + 1),
            "window capacity {} grew",
            w.capacity()
        );
    }

    #[test]
    fn observation_bundles_fields() {
        let p = Probes::new();
        p.record_arrival(10);
        let _ = p.sample_rates(0.0);
        p.record_completion(4, 8_000_000);
        p.record_emission(8);
        p.record_arrival(10);
        let obs = p.observe(2.0, 7, 2, 8);
        assert_eq!(obs.queue_len, 7);
        assert_eq!(obs.cores, 2);
        assert_eq!(obs.instances, 8);
        assert!(obs.arrival_rate > 0.0);
        assert!((obs.selectivity - 2.0).abs() < 1e-9);
    }
}
