//! Core-bounded worker pool — the paper's Java `ForkJoinPool` analogue.
//!
//! A container grants a flake `cores` cores; the flake runs up to
//! `cores × α` data-parallel pellet instances (§III, α = 4).  Each worker
//! thread owns one pellet instance.  The pool is resizable at runtime:
//! growing spawns workers, shrinking signals individual workers to exit
//! after their current work item — this is the mechanism behind the
//! dynamic adaptation strategy's core scaling.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

/// The per-worker body: loops until the passed flag is set.  Index is the
/// worker's instance number (stable for its lifetime).
pub type WorkerBody = Arc<dyn Fn(usize, &AtomicBool) + Send + Sync>;

struct Worker {
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

/// Resizable worker pool.
pub struct CorePool {
    body: WorkerBody,
    workers: Mutex<Vec<Worker>>,
    next_index: Mutex<usize>,
    label: String,
}

impl CorePool {
    /// Create a pool with `n` workers running `body`.
    pub fn new(label: &str, n: usize, body: WorkerBody) -> CorePool {
        let pool = CorePool {
            body,
            workers: Mutex::new(Vec::new()),
            next_index: Mutex::new(0),
            label: label.to_string(),
        };
        pool.resize(n);
        pool
    }

    /// Current worker count (including workers winding down).
    pub fn size(&self) -> usize {
        self.workers
            .lock()
            .expect("pool poisoned")
            .iter()
            .filter(|w| !w.stop.load(Ordering::SeqCst))
            .count()
    }

    /// Grow or shrink to `n` active workers.  Shrinking is cooperative:
    /// signalled workers finish their current item first.
    pub fn resize(&self, n: usize) {
        let mut workers = self.workers.lock().expect("pool poisoned");
        // Reap finished workers.
        workers.retain_mut(|w| {
            if w.stop.load(Ordering::SeqCst) {
                if let Some(j) = w.join.take() {
                    if j.is_finished() {
                        let _ = j.join();
                        return false;
                    }
                    w.join = Some(j);
                }
            }
            true
        });
        let active: Vec<usize> = workers
            .iter()
            .enumerate()
            .filter(|(_, w)| !w.stop.load(Ordering::SeqCst))
            .map(|(i, _)| i)
            .collect();
        if active.len() < n {
            for _ in active.len()..n {
                let stop = Arc::new(AtomicBool::new(false));
                let stop2 = Arc::clone(&stop);
                let body = Arc::clone(&self.body);
                let mut idx_guard =
                    self.next_index.lock().expect("pool poisoned");
                let index = *idx_guard;
                *idx_guard += 1;
                drop(idx_guard);
                let join = thread::Builder::new()
                    .name(format!("{}-w{}", self.label, index))
                    .spawn(move || body(index, &stop2))
                    .expect("spawn pool worker");
                workers.push(Worker { stop, join: Some(join) });
            }
        } else if active.len() > n {
            for &i in active.iter().rev().take(active.len() - n) {
                workers[i].stop.store(true, Ordering::SeqCst);
            }
        }
    }

    /// Stop all workers and join them.
    pub fn shutdown(&self) {
        let mut workers = self.workers.lock().expect("pool poisoned");
        for w in workers.iter() {
            w.stop.store(true, Ordering::SeqCst);
        }
        for w in workers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
        workers.clear();
    }
}

impl Drop for CorePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn counting_body(
        running: Arc<AtomicUsize>,
        peak: Arc<AtomicUsize>,
    ) -> WorkerBody {
        Arc::new(move |_idx, stop| {
            let n = running.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(n, Ordering::SeqCst);
            while !stop.load(Ordering::SeqCst) {
                thread::sleep(Duration::from_millis(1));
            }
            running.fetch_sub(1, Ordering::SeqCst);
        })
    }

    #[test]
    fn spawns_n_workers() {
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let pool = CorePool::new(
            "t",
            4,
            counting_body(Arc::clone(&running), Arc::clone(&peak)),
        );
        // Wait for workers to come up.
        for _ in 0..100 {
            if running.load(Ordering::SeqCst) == 4 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(running.load(Ordering::SeqCst), 4);
        assert_eq!(pool.size(), 4);
        pool.shutdown();
        assert_eq!(running.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn resize_up_and_down() {
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let pool = CorePool::new(
            "t",
            2,
            counting_body(Arc::clone(&running), Arc::clone(&peak)),
        );
        pool.resize(6);
        for _ in 0..100 {
            if running.load(Ordering::SeqCst) == 6 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(running.load(Ordering::SeqCst), 6);
        pool.resize(1);
        for _ in 0..200 {
            if running.load(Ordering::SeqCst) == 1 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(running.load(Ordering::SeqCst), 1);
        assert_eq!(pool.size(), 1);
        pool.shutdown();
    }

    #[test]
    fn worker_indexes_are_unique() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let pool = CorePool::new(
            "t",
            3,
            Arc::new(move |idx, stop| {
                seen2.lock().unwrap().push(idx);
                while !stop.load(Ordering::SeqCst) {
                    thread::sleep(Duration::from_millis(1));
                }
            }),
        );
        thread::sleep(Duration::from_millis(30));
        pool.resize(5);
        thread::sleep(Duration::from_millis(30));
        pool.shutdown();
        let mut got = seen.lock().unwrap().clone();
        got.sort();
        got.dedup();
        assert_eq!(got.len(), 5, "{got:?}");
    }
}
