//! The flake: per-pellet executor (§III).
//!
//! A flake owns the input queues of one pellet, aligns/windows arriving
//! messages according to the pellet's design-pattern annotations, runs
//! data-parallel pellet instances on a core-bounded [`CorePool`]
//! (`cores × α` instances), routes outputs through the split-mode
//! [`OutputRouter`], and supports **in-place dynamic task update** — the
//! paper's headline application-dynamism mechanism — in both synchronous
//! and asynchronous flavors.
//!
//! Threads: one *dispatcher* drains input queues and forms [`PortIo`] work
//! items; `cores × α` *workers* each own a pellet instance and execute work
//! items.  Backpressure propagates through the bounded queues.

mod checkpoint;
mod pool;
mod probes;
mod router;

pub use checkpoint::FlakeCheckpoint;
pub use pool::{CorePool, WorkerBody};
pub use probes::{FlakeObservation, Probes};
pub use router::OutputRouter;

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::channel::{
    ChannelBackend, EndpointTable, ShardedQueue, SyncQueue, TcpReceiver,
    Transport,
};
use crate::error::{FloeError, Result};
use crate::graph::{
    InPortSpec, MergeMode, OutPortSpec, PelletSpec, TriggerMode, WindowSpec,
};
use crate::message::{Landmark, Message};
use crate::pellet::{
    Pellet, PelletContext, PelletFactory, PortIo, StateObject,
};
use crate::ALPHA;

/// Default dispatcher/transport batch size: how many messages move per
/// lock acquisition (and per TCP syscall) on the hot path.
pub const DEFAULT_BATCH_SIZE: usize = 64;

/// How long a lingering TCP receiver adopted after a relocation may
/// sit with no live connections and no traffic before tearing itself
/// down (every sender re-resolves and rebinds well inside this).
const ADOPTED_RECEIVER_IDLE: Duration = Duration::from_secs(2);

/// Flake construction parameters, usually derived from a [`PelletSpec`].
#[derive(Clone)]
pub struct FlakeConfig {
    pub pellet_id: String,
    pub class: String,
    pub inputs: Vec<InPortSpec>,
    pub outputs: Vec<OutPortSpec>,
    pub merge: MergeMode,
    pub trigger: TriggerMode,
    pub sequential: bool,
    pub stateful: bool,
    /// Initial core allocation.
    pub cores: usize,
    /// Instances per core (paper: α = 4).
    pub alpha: usize,
    /// Input queue capacity per port (backpressure bound, split across
    /// the port's shards).
    pub queue_capacity: usize,
    /// Messages moved per batched queue operation on the hot path.
    /// 1 disables batching (the pre-batching single-message path).
    pub batch_size: usize,
    /// Producer shards per input port (see
    /// [`crate::channel::ShardedQueue`]).
    pub input_shards: usize,
    /// Which primitive backs each input-port shard: the lock-free ring
    /// (default) or the mutex reference queue.
    pub channel_backend: ChannelBackend,
    /// Sequence-numbered dedup at the dispatcher: drop any non-landmark
    /// message whose `seq` is at or below the port's high-water mark.
    /// Sound only on single-producer ports whose delivery order follows
    /// message creation order (then a smaller-or-equal `seq` can only
    /// be a replay); off by default.  Checkpoints capture the
    /// watermarks, so a restored replacement discards duplicates that
    /// at-least-once redelivery replays into it.  Ignored under
    /// [`MergeMode::Synchronous`]: dropping one port's duplicate
    /// would misalign the tuple merge.
    pub dedup: bool,
}

impl FlakeConfig {
    pub fn from_spec(spec: &PelletSpec) -> FlakeConfig {
        FlakeConfig {
            pellet_id: spec.id.clone(),
            class: spec.class.clone(),
            inputs: spec.inputs.clone(),
            outputs: spec.outputs.clone(),
            merge: spec.merge,
            trigger: spec.trigger,
            sequential: spec.sequential,
            stateful: spec.stateful,
            cores: spec.cores.unwrap_or(1),
            alpha: ALPHA,
            queue_capacity: 4096,
            batch_size: DEFAULT_BATCH_SIZE,
            input_shards: crate::channel::DEFAULT_SHARDS,
            channel_backend: ChannelBackend::default(),
            dedup: false,
        }
    }

    fn instances_for(&self, cores: usize) -> usize {
        if self.sequential {
            1
        } else {
            (cores * self.alpha).max(1)
        }
    }
}

/// Per-pellet telemetry instruments, resolved once at spawn when the
/// launch enabled telemetry (`Shared.telemetry = None` otherwise, so
/// the off path costs a single branch per batch).
struct FlakeTelemetry {
    batch: Arc<crate::telemetry::Histogram>,
    service: Arc<crate::telemetry::Histogram>,
    dedup_drops: Arc<crate::telemetry::Counter>,
    e2e: Arc<crate::telemetry::Histogram>,
    sampler: crate::telemetry::Sampler,
    /// Sink flakes (no output ports) record sampled e2e latency.
    sink: bool,
}

impl FlakeTelemetry {
    fn for_pellet(cfg: &FlakeConfig) -> FlakeTelemetry {
        let id = &cfg.pellet_id;
        FlakeTelemetry {
            batch: crate::telemetry::hist_flake_batch(id),
            service: crate::telemetry::hist_flake_service(id),
            dedup_drops: crate::telemetry::ctr_flake_dedup_drops(id),
            e2e: crate::telemetry::hist_e2e_latency(id),
            sampler: crate::telemetry::Sampler::new(
                crate::telemetry::sample_every(),
            ),
            sink: cfg.outputs.is_empty(),
        }
    }
}

struct Shared {
    cfg: FlakeConfig,
    ports: HashMap<String, Arc<ShardedQueue<Message>>>,
    port_order: Vec<String>,
    /// Per-port dedup high-water marks (highest `seq` dispatched);
    /// only consulted when `cfg.dedup` is set.  Relaxed ordering is
    /// enough: each port is read and advanced by the single dispatcher
    /// thread, checkpoints read it only after draining.
    watermarks: HashMap<String, AtomicU64>,
    ready: Arc<SyncQueue<PortIo>>,
    router: RwLock<OutputRouter>,
    state: StateObject,
    factory: RwLock<PelletFactory>,
    version: AtomicU64,
    probes: Probes,
    paused: AtomicBool,
    /// Bumped every time the dispatcher observes `paused` at the top
    /// of its loop.  A bump proves the dispatcher holds no in-hand
    /// batch (anything popped earlier reached the ready queue), which
    /// is what [`Flake::quiesce`] needs: `paused` + empty counters
    /// alone can race a batch sitting between a queue pop and the
    /// ready-queue push.
    pause_epoch: AtomicU64,
    /// Set when the dispatcher thread exits (queues closed), so
    /// quiesce never waits on a dead dispatcher for an epoch bump.
    dispatcher_done: AtomicBool,
    interrupt: Arc<AtomicBool>,
    stop: AtomicBool,
    cores: AtomicUsize,
    active_instances: AtomicUsize,
    /// `Some` iff telemetry was enabled when this flake spawned.
    telemetry: Option<FlakeTelemetry>,
}

impl Shared {
    /// Execute one work item on a pellet instance, routing its
    /// emissions.  The caller has already accounted the in-flight
    /// increment (via [`SyncQueue::pop_timeout_counted`], under the
    /// ready-queue lock, so quiesce/drain checks never see the item
    /// in neither place); this only decrements when done.
    fn run_item(
        &self,
        pellet: &mut Box<dyn Pellet>,
        ctx: &mut PelletContext,
        item: PortIo,
    ) {
        let msgs = item.messages().len() as u64;
        // Oldest ingest stamp across the batch (`created_us` already
        // rides the wire) — captured before compute consumes the item,
        // propagated into emissions below so downstream sinks measure
        // true ingest→sink latency.  `u64::MAX` = nothing to carry.
        let origin_us = match &self.telemetry {
            Some(_) => item
                .messages()
                .iter()
                .map(|m| m.created_us)
                .min()
                .unwrap_or(u64::MAX),
            None => u64::MAX,
        };
        let start = Instant::now();
        let result = pellet.compute(item, ctx);
        let nanos = start.elapsed().as_nanos() as u64;
        self.probes.record_completion(msgs, nanos);
        if let Some(tl) = &self.telemetry {
            tl.service.record(nanos);
            if tl.sink
                && origin_us != u64::MAX
                && tl.sampler.tick()
            {
                let age_us = crate::message::now_us()
                    .saturating_sub(origin_us);
                tl.e2e.record(age_us.saturating_mul(1000));
            }
        }
        match result {
            Ok(()) => self.flush_emissions_stamped(ctx, origin_us),
            Err(e) => {
                crate::log_error!(
                    "pellet {} compute failed: {e}",
                    self.cfg.pellet_id
                );
            }
        }
        self.probes.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    fn flush_emissions(&self, ctx: &mut PelletContext) {
        self.flush_emissions_stamped(ctx, u64::MAX);
    }

    /// Route pending emissions; when an origin ingest stamp is known
    /// (`origin_us != u64::MAX`), carry it onto every emitted message
    /// so the e2e clock keeps ticking across hops.  `min` keeps the
    /// oldest stamp if the pellet emitted a message it received.
    fn flush_emissions_stamped(
        &self,
        ctx: &mut PelletContext,
        origin_us: u64,
    ) {
        let mut emitted = ctx.take_emitted();
        if origin_us != u64::MAX {
            for (_, m) in emitted.iter_mut() {
                m.created_us = m.created_us.min(origin_us);
            }
        }
        if !emitted.is_empty() {
            self.route_emissions(emitted);
        }
    }

    fn route_emissions(&self, emitted: Vec<(String, Message)>) {
        let router = self.router.read().expect("router poisoned");
        // Group by port (order preserved within a port; ordering across
        // ports carries no contract) so every port's emissions move as
        // one batch through the router and its transports.
        let mut by_port: Vec<(String, Vec<Message>)> = Vec::new();
        for (port, msg) in emitted {
            match by_port.iter().position(|(p, _)| *p == port) {
                Some(i) => by_port[i].1.push(msg),
                None => by_port.push((port, vec![msg])),
            }
        }
        for (port, msgs) in by_port {
            self.probes.record_emission(msgs.len() as u64);
            if let Err(e) = router.route_batch(&port, msgs) {
                crate::log_error!(
                    "pellet {} route to '{port}' failed: {e}",
                    self.cfg.pellet_id
                );
            }
        }
    }

    fn queue_len(&self) -> usize {
        self.ports.values().map(|q| q.len()).sum::<usize>()
            + self.ready.len()
    }

    /// Sequence-numbered dedup (when `cfg.dedup` is on): drop every
    /// non-landmark message at or below the port's watermark and
    /// advance the watermark past what survives.  Returns the number
    /// of duplicates dropped.  Called from the dispatcher right after
    /// each pop, before the batch becomes visible to workers.
    fn dedup_filter(&self, port: &str, buf: &mut Vec<Message>) -> usize {
        if !self.cfg.dedup {
            return 0;
        }
        let Some(w) = self.watermarks.get(port) else {
            return 0;
        };
        let mut mark = w.load(Ordering::Relaxed);
        let before = buf.len();
        buf.retain(|m| {
            if m.is_landmark() {
                return true;
            }
            if m.seq <= mark {
                return false;
            }
            mark = m.seq;
            true
        });
        w.store(mark, Ordering::Relaxed);
        let dropped = before - buf.len();
        if dropped > 0 {
            if let Some(tl) = &self.telemetry {
                tl.dedup_drops.add(dropped as u64);
            }
            crate::log_debug!(
                "flake {}: dedup dropped {dropped} replayed message(s) \
                 on '{port}'",
                self.cfg.pellet_id
            );
        }
        dropped
    }
}

/// This flake's publication in an [`EndpointTable`]: which table its
/// endpoints live in, and the token guarding the entry so a displaced
/// incarnation can never unpublish its replacement.
struct EndpointBinding {
    table: Arc<EndpointTable>,
    token: u64,
}

/// TCP ingress state: the primary bound endpoint (at most one per
/// flake) plus any lingering receivers adopted from a previous
/// incarnation after a relocation (they keep serving the old physical
/// endpoint — delivering through the endpoint table, which now points
/// here — until remote senders rebind; torn down with the flake).
struct TcpState {
    endpoint: Option<String>,
    receivers: Vec<TcpReceiver>,
}

/// A running flake.  Cheap to clone handles are not provided; the
/// coordinator owns flakes via `Arc<Flake>`.
pub struct Flake {
    shared: Arc<Shared>,
    pool: CorePool,
    dispatcher: Mutex<Option<thread::JoinHandle<()>>>,
    endpoints: Mutex<Option<EndpointBinding>>,
    tcp: Mutex<TcpState>,
}

impl Flake {
    /// Build and start a flake: spawns the dispatcher and the initial
    /// worker pool.  Wiring of outputs happens afterwards via
    /// [`Flake::wire_output`] — the coordinator activates sources last, so
    /// a flake may run before its upstream is wired but never emits before
    /// its own outputs are wired.
    pub fn start(cfg: FlakeConfig, factory: PelletFactory) -> Arc<Flake> {
        let mut ports = HashMap::new();
        let mut port_order = Vec::new();
        // Synchronous merge aligns one message per port in arrival order,
        // so its ports stay single-shard: a sharded sweep would interleave
        // per-producer FIFOs out of arrival order and break alignment.
        let shards = if cfg.merge == MergeMode::Synchronous {
            1
        } else {
            cfg.input_shards.max(1)
        };
        for p in &cfg.inputs {
            ports.insert(
                p.name.clone(),
                Arc::new(ShardedQueue::with_backend(
                    shards,
                    cfg.queue_capacity,
                    cfg.channel_backend,
                )),
            );
            port_order.push(p.name.clone());
        }
        let mut router = OutputRouter::new();
        for o in &cfg.outputs {
            router.add_port(&o.name, o.split);
        }
        let ready = Arc::new(SyncQueue::new((cfg.alpha * 4).max(16)));
        let cores = cfg.cores.max(1);
        let watermarks = cfg
            .inputs
            .iter()
            .map(|p| (p.name.clone(), AtomicU64::new(0)))
            .collect();
        let telemetry = crate::telemetry::enabled()
            .then(|| FlakeTelemetry::for_pellet(&cfg));
        let shared = Arc::new(Shared {
            ports,
            port_order,
            watermarks,
            ready,
            router: RwLock::new(router),
            state: StateObject::new(),
            factory: RwLock::new(factory),
            version: AtomicU64::new(1),
            probes: Probes::new(),
            paused: AtomicBool::new(false),
            pause_epoch: AtomicU64::new(0),
            dispatcher_done: AtomicBool::new(false),
            interrupt: Arc::new(AtomicBool::new(false)),
            stop: AtomicBool::new(false),
            cores: AtomicUsize::new(cores),
            active_instances: AtomicUsize::new(0),
            telemetry,
            cfg,
        });

        // Worker body: owns a pellet instance, re-created when the logic
        // version changes (dynamic task update).
        let worker_shared = Arc::clone(&shared);
        let body: WorkerBody = Arc::new(move |index, stop_flag| {
            worker_loop(&worker_shared, index, stop_flag);
        });
        let instances = shared.cfg.instances_for(cores);
        let label = format!("flake-{}", shared.cfg.pellet_id);
        let pool = CorePool::new(&label, instances, body);

        // Dispatcher thread.
        let disp_shared = Arc::clone(&shared);
        let dispatcher = thread::Builder::new()
            .name(format!("flake-{}-disp", shared.cfg.pellet_id))
            .spawn(move || {
                dispatcher_loop(&disp_shared);
                disp_shared.dispatcher_done.store(true, Ordering::SeqCst);
            })
            .expect("spawn dispatcher");

        Arc::new(Flake {
            shared,
            pool,
            dispatcher: Mutex::new(Some(dispatcher)),
            endpoints: Mutex::new(None),
            tcp: Mutex::new(TcpState {
                endpoint: None,
                receivers: Vec::new(),
            }),
        })
    }

    /// Convenience: start from a graph spec with the default config.
    pub fn from_spec(spec: &PelletSpec, factory: PelletFactory) -> Arc<Flake> {
        Flake::start(FlakeConfig::from_spec(spec), factory)
    }

    pub fn pellet_id(&self) -> &str {
        &self.shared.cfg.pellet_id
    }

    pub fn class(&self) -> &str {
        &self.shared.cfg.class
    }

    /// Input queue for a port — the coordinator wires upstream transports
    /// to this, and tests/apps inject messages directly.
    ///
    /// Remote ingress caveat: a `TcpReceiver` built externally over
    /// these queue handles is invisible to the runtime and cannot
    /// follow a relocation — attach remote ingress through
    /// [`Flake::serve_tcp`] instead, which registers the port map in
    /// the endpoint table so the stream survives a move.
    pub fn input_queue(
        &self,
        port: &str,
    ) -> Result<Arc<ShardedQueue<Message>>> {
        self.shared.ports.get(port).cloned().ok_or_else(|| {
            FloeError::Graph(format!(
                "flake {}: no input port '{port}'",
                self.shared.cfg.pellet_id
            ))
        })
    }

    /// Inject a message into an input port (graph ingress).
    pub fn inject(&self, port: &str, msg: Message) -> Result<()> {
        self.shared.probes.record_arrival(1);
        self.input_queue(port)?
            .push(msg)
            .map_err(|_| FloeError::Channel("flake input closed".into()))
    }

    /// Wire an outgoing edge from `port` to a sink transport.
    pub fn wire_output(
        &self,
        port: &str,
        transport: Arc<dyn Transport>,
    ) -> Result<()> {
        self.shared
            .router
            .write()
            .expect("router poisoned")
            .add_target(port, transport)
    }

    /// Atomically replace a port's outgoing edges (graph surgery).
    /// Routing threads observe either the old wiring or the new one,
    /// never a mix; callers quiesce the flake first so no pre-cut
    /// message is still in flight when the swap lands.
    pub fn replace_output_targets(
        &self,
        port: &str,
        targets: Vec<Arc<dyn Transport>>,
    ) -> Result<()> {
        self.shared
            .router
            .write()
            .expect("router poisoned")
            .replace_targets(port, targets)
    }

    /// Drop every outgoing edge of a port (graph surgery: edge removal,
    /// pellet retirement).
    pub fn clear_output_targets(&self, port: &str) -> Result<()> {
        self.shared
            .router
            .write()
            .expect("router poisoned")
            .clear_targets(port)
    }

    /// Broadcast a landmark on every output port — used by the
    /// recomposition engine to separate pre-surgery from post-surgery
    /// streams.  Delivery is best-effort and **non-blocking**: a full
    /// queue (e.g. a paused sibling in the same surgery's pause set)
    /// drops the marker for that edge instead of wedging the caller,
    /// and errors (a sink already shut down during teardown) are
    /// logged, not returned.
    pub fn emit_landmark(&self, landmark: Landmark) {
        let router = self.shared.router.read().expect("router poisoned");
        for o in &self.shared.cfg.outputs {
            let msg = Message::landmark(landmark.clone());
            match router.try_broadcast(&o.name, msg) {
                Ok(n) if n < router.target_count(&o.name) => {
                    crate::log_warn!(
                        "flake {}: landmark on '{}' reached {n}/{} edges \
                         (full queues dropped the rest)",
                        self.shared.cfg.pellet_id,
                        o.name,
                        router.target_count(&o.name)
                    );
                }
                Ok(_) => {}
                Err(e) => {
                    crate::log_warn!(
                        "flake {}: landmark on '{}' failed: {e}",
                        self.shared.cfg.pellet_id,
                        o.name
                    );
                }
            }
        }
    }

    /// The pellet's state object (survives updates; pre-seed configuration
    /// like `floe.builtin.Delay`'s `delay_secs` here).
    pub fn state(&self) -> &StateObject {
        &self.shared.state
    }

    /// Current logic version (starts at 1, +1 per dynamic update).
    pub fn version(&self) -> u64 {
        self.shared.version.load(Ordering::SeqCst)
    }

    /// Currently allocated cores.
    pub fn cores(&self) -> usize {
        self.shared.cores.load(Ordering::SeqCst)
    }

    /// Number of live pellet instances.
    pub fn instances(&self) -> usize {
        self.shared.active_instances.load(Ordering::SeqCst)
    }

    /// Total buffered input messages.
    pub fn queue_len(&self) -> usize {
        self.shared.queue_len()
    }

    /// Work items dispatched but not yet picked up by an instance.
    pub fn ready_len(&self) -> usize {
        self.shared.ready.len()
    }

    /// Names of this flake's input ports.
    pub fn input_ports(&self) -> Vec<String> {
        self.shared.port_order.clone()
    }

    /// Change the core allocation at runtime (adaptation strategies call
    /// this through the container).  Instances scale by α.
    pub fn set_cores(&self, cores: usize) {
        let cores = cores.max(1);
        self.shared.cores.store(cores, Ordering::SeqCst);
        self.pool.resize(self.shared.cfg.instances_for(cores));
    }

    /// Names of this flake's output ports.
    pub fn output_ports(&self) -> Vec<String> {
        self.shared.cfg.outputs.iter().map(|o| o.name.clone()).collect()
    }

    /// A copy of the construction config with `cores` reflecting the
    /// *current* grant rather than the launch value, so a relocation
    /// replacement keeps the allocation the adaptation loop has grown
    /// (and the target container must actually have room for it).
    pub fn config(&self) -> FlakeConfig {
        let mut cfg = self.shared.cfg.clone();
        cfg.cores = self.cores();
        cfg
    }

    /// Bind a TCP receiver (`127.0.0.1:port`, 0 = ephemeral) that
    /// decodes framed messages into this flake's input port queues —
    /// the remote-edge ingress.  Returns the bound endpoint.  At most
    /// one primary receiver per flake.
    ///
    /// When the flake is published in an [`EndpointTable`] (every
    /// coordinator-launched flake is), the receiver registers the port
    /// map **in the table** instead of capturing queue handles: frames
    /// resolve `(flake-id, port)` at delivery time, the bound endpoint
    /// is recorded under the flake's logical address, and the flake
    /// stays fully relocatable — the recomposition engine republishes
    /// the endpoints at the new container and both ends of the TCP
    /// edge follow.  An unpublished (standalone) flake falls back to
    /// the captured-map receiver.
    pub fn serve_tcp(&self, port: u16) -> Result<String> {
        let binding = {
            let guard =
                self.endpoints.lock().expect("endpoint binding poisoned");
            guard.as_ref().map(|b| (Arc::clone(&b.table), b.token))
        };
        match binding {
            Some((table, token)) => {
                let ep = self.start_tcp(port, Some(&table))?;
                table.set_tcp(self.pellet_id(), token, &ep)?;
                Ok(ep)
            }
            None => self.start_tcp(port, None),
        }
    }

    /// Bind a **logical** TCP receiver against `table` without
    /// recording the endpoint there yet — used by the recomposition
    /// engine on a relocation replacement, whose publication happens
    /// atomically at cut-over ([`Flake::publish_endpoints`] includes
    /// the pending endpoint).
    pub(crate) fn serve_tcp_in(
        &self,
        port: u16,
        table: &Arc<EndpointTable>,
    ) -> Result<String> {
        self.start_tcp(port, Some(table))
    }

    fn start_tcp(
        &self,
        port: u16,
        table: Option<&Arc<EndpointTable>>,
    ) -> Result<String> {
        let mut tcp = self.tcp.lock().expect("tcp state poisoned");
        if tcp.endpoint.is_some() {
            return Err(FloeError::Channel(format!(
                "flake {}: tcp receiver already bound",
                self.shared.cfg.pellet_id
            )));
        }
        let rx = match table {
            Some(t) => TcpReceiver::start_logical(
                port,
                self.pellet_id(),
                Arc::clone(t),
            )?,
            None => {
                TcpReceiver::start(port, self.shared.ports.clone())?
            }
        };
        let endpoint = rx.endpoint();
        tcp.endpoint = Some(endpoint.clone());
        tcp.receivers.push(rx);
        Ok(endpoint)
    }

    /// True when a live [`TcpReceiver`] feeds this flake's inputs.
    pub fn has_tcp_input(&self) -> bool {
        !self.tcp.lock().expect("tcp state poisoned").receivers.is_empty()
    }

    /// The primary TCP ingress endpoint, when one is bound.
    pub fn tcp_endpoint(&self) -> Option<String> {
        self.tcp.lock().expect("tcp state poisoned").endpoint.clone()
    }

    /// Publish (or republish) this flake's endpoints — every input
    /// port queue plus the pending TCP ingress endpoint — into `table`
    /// under the flake's logical address, and remember the binding so
    /// shutdown unpublishes it (token-guarded: a stale incarnation
    /// can never tear down its replacement's entry).
    pub(crate) fn publish_endpoints(&self, table: &Arc<EndpointTable>) {
        let tcp =
            self.tcp.lock().expect("tcp state poisoned").endpoint.clone();
        let token = table.publish(
            self.pellet_id(),
            self.shared.ports.clone(),
            tcp,
        );
        *self.endpoints.lock().expect("endpoint binding poisoned") =
            Some(EndpointBinding { table: Arc::clone(table), token });
    }

    /// Remove this flake's endpoint publication if it is still the
    /// current one (no-op for a displaced husk whose replacement has
    /// republished).
    pub(crate) fn unpublish_endpoints(&self) {
        if let Some(b) = self
            .endpoints
            .lock()
            .expect("endpoint binding poisoned")
            .take()
        {
            b.table.unpublish_if(self.pellet_id(), b.token);
        }
    }

    /// Detach every TCP receiver (relocation: the replacement adopts
    /// them so remote senders that have not rebound yet keep a live
    /// socket whose deliveries resolve to the replacement's queues).
    /// The recorded endpoint is kept so a cut-over rollback can
    /// republish this incarnation unchanged.
    pub(crate) fn take_tcp_receivers(&self) -> Vec<TcpReceiver> {
        std::mem::take(
            &mut self.tcp.lock().expect("tcp state poisoned").receivers,
        )
    }

    /// Adopt lingering receivers from a displaced incarnation (see
    /// [`Flake::take_tcp_receivers`]).  They are shut down with this
    /// flake; the primary endpoint is unaffected.  Each adopted
    /// receiver gets an idle-timeout teardown: once every remote
    /// sender has rebound to the primary endpoint and the old socket
    /// has been silent for [`ADOPTED_RECEIVER_IDLE`], the lingering
    /// listener retires itself instead of living until the next
    /// relocation or shutdown.
    pub(crate) fn adopt_tcp_receivers(&self, extra: Vec<TcpReceiver>) {
        let mut tcp = self.tcp.lock().expect("tcp state poisoned");
        for rx in extra {
            rx.enable_idle_teardown(ADOPTED_RECEIVER_IDLE);
            tcp.receivers.push(rx);
        }
    }

    /// Per-port dedup high-water marks (checkpoint capture).
    pub(crate) fn dedup_watermarks(&self) -> BTreeMap<String, u64> {
        self.shared
            .watermarks
            .iter()
            .map(|(p, w)| (p.clone(), w.load(Ordering::Relaxed)))
            .collect()
    }

    /// Seed the dedup watermarks from a checkpoint — done *before*
    /// replaying its queued messages, whose sequence numbers all lie
    /// above the captured marks (they had not been dispatched yet).
    pub(crate) fn set_dedup_watermarks(
        &self,
        seen: &BTreeMap<String, u64>,
    ) {
        for (port, mark) in seen {
            if let Some(w) = self.shared.watermarks.get(port) {
                w.store(*mark, Ordering::Relaxed);
            }
        }
    }

    /// The factory currently producing pellet instances.  After dynamic
    /// updates this may differ from what the class name resolves to in
    /// the registry, so relocation clones this instead of re-resolving.
    pub fn current_factory(&self) -> PelletFactory {
        self.shared.factory.read().expect("factory poisoned").clone()
    }

    /// Pause intake (dispatcher stops forming work items; queues buffer).
    pub fn pause(&self) {
        self.shared.paused.store(true, Ordering::SeqCst);
    }

    /// Pause intake and wait for dispatched work items and in-flight
    /// compute to finish (long-running instances see
    /// `ctx.interrupted()`, pull sources yield).  Input queues keep
    /// buffering under backpressure.  The flake stays paused on both
    /// success and timeout; callers resume it (or tear it down) when
    /// the surgery completes.
    ///
    /// Waits first for the dispatcher to *acknowledge* the pause (one
    /// `pause_epoch` bump), so a batch in the dispatcher's hands —
    /// popped from an input queue but not yet in the ready queue, and
    /// therefore invisible to every counter — cannot slip past the
    /// drain check below.  Caveat: a count/time window accumulating in
    /// the dispatcher stays buffered there across a quiesce (the same
    /// exposure `checkpoint` has always had); it is flushed when the
    /// flake resumes, but is not visible to a relocation handoff.
    pub fn quiesce(&self, timeout: Duration) -> Result<()> {
        let epoch = self.shared.pause_epoch.load(Ordering::SeqCst);
        self.pause();
        self.shared.interrupt.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + timeout;
        let fail = |shared: &Shared| {
            shared.interrupt.store(false, Ordering::SeqCst);
            Err(FloeError::Pellet(format!(
                "flake {}: quiesce timed out",
                shared.cfg.pellet_id
            )))
        };
        while self.shared.pause_epoch.load(Ordering::SeqCst) == epoch
            && !self.shared.dispatcher_done.load(Ordering::SeqCst)
        {
            if Instant::now() > deadline {
                return fail(&self.shared);
            }
            thread::sleep(Duration::from_millis(1));
        }
        while !self.shared.ready.is_empty()
            || self.shared.probes.inflight.load(Ordering::SeqCst) > 0
        {
            if Instant::now() > deadline {
                return fail(&self.shared);
            }
            thread::sleep(Duration::from_millis(1));
        }
        self.shared.interrupt.store(false, Ordering::SeqCst);
        Ok(())
    }

    /// Resume intake.
    pub fn resume(&self) {
        self.shared.paused.store(false, Ordering::SeqCst);
    }

    pub fn is_paused(&self) -> bool {
        self.shared.paused.load(Ordering::SeqCst)
    }

    /// Observation snapshot for adaptation strategies.
    pub fn observe(&self, t: f64) -> FlakeObservation {
        self.shared.probes.observe(
            t,
            self.queue_len(),
            self.cores(),
            self.instances(),
        )
    }

    /// Probe counters (tests, metrics endpoints).
    pub fn probes(&self) -> &Probes {
        &self.shared.probes
    }

    /// **Dynamic task update** (§II-B).  Swap the pellet logic in place.
    ///
    /// * `sync = false` (asynchronous): zero downtime — the new factory is
    ///   published immediately; each instance switches after finishing its
    ///   current message.  Old and new outputs may interleave.
    /// * `sync = true` (synchronous): intake pauses, in-flight messages run
    ///   to completion (long-running instances see `ctx.interrupted()`),
    ///   the swap happens, then intake resumes.  Downtime is bounded by the
    ///   in-flight work.
    ///
    /// Pending input messages are retained; the state object survives.
    /// With `landmark = true` the new logic announces itself downstream
    /// with an `Update` landmark.
    pub fn update_pellet(
        &self,
        new_factory: PelletFactory,
        sync: bool,
        landmark: bool,
    ) -> Result<u64> {
        let new_version;
        if sync {
            self.pause();
            self.shared.interrupt.store(true, Ordering::SeqCst);
            // Drain: dispatcher is paused, wait for ready queue + in-flight.
            let deadline = Instant::now() + Duration::from_secs(30);
            while !self.shared.ready.is_empty()
                || self.shared.probes.inflight.load(Ordering::SeqCst) > 0
            {
                if Instant::now() > deadline {
                    self.shared.interrupt.store(false, Ordering::SeqCst);
                    self.resume();
                    return Err(FloeError::Pellet(format!(
                        "flake {}: sync update drain timed out",
                        self.shared.cfg.pellet_id
                    )));
                }
                thread::sleep(Duration::from_millis(1));
            }
            *self.shared.factory.write().expect("factory poisoned") =
                new_factory;
            new_version =
                self.shared.version.fetch_add(1, Ordering::SeqCst) + 1;
            self.shared.interrupt.store(false, Ordering::SeqCst);
            self.resume();
        } else {
            *self.shared.factory.write().expect("factory poisoned") =
                new_factory;
            new_version =
                self.shared.version.fetch_add(1, Ordering::SeqCst) + 1;
        }
        if landmark {
            let router = self.shared.router.read().expect("router poisoned");
            for o in &self.shared.cfg.outputs {
                let _ = router.route(
                    &o.name,
                    Message::landmark(Landmark::Update {
                        version: new_version,
                    }),
                );
            }
        }
        crate::log_info!(
            "flake {}: updated to version {new_version} ({})",
            self.shared.cfg.pellet_id,
            if sync { "sync" } else { "async" }
        );
        Ok(new_version)
    }

    /// Wait until all input queues and in-flight work are empty (tests and
    /// graceful drains).  Returns false on timeout.  The idle condition
    /// must hold across consecutive checks: a message can transiently be
    /// in neither a queue nor the in-flight counter while a thread moves
    /// it between the two.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut idle_streak = 0;
        loop {
            let idle = self.queue_len() == 0
                && self.shared.probes.inflight.load(Ordering::SeqCst) == 0;
            if idle {
                idle_streak += 1;
                if idle_streak >= 3 {
                    return true;
                }
            } else {
                idle_streak = 0;
            }
            if Instant::now() > deadline {
                return false;
            }
            thread::sleep(Duration::from_millis(2));
        }
    }

    /// Stop the flake: close queues, stop dispatcher and workers, and
    /// withdraw its endpoint publication (token-guarded, so a husk
    /// displaced by relocation leaves its replacement's entry alone).
    pub fn shutdown(&self) {
        {
            let mut tcp = self.tcp.lock().expect("tcp state poisoned");
            for rx in tcp.receivers.iter_mut() {
                rx.shutdown();
            }
            tcp.receivers.clear();
            tcp.endpoint = None;
        }
        self.unpublish_endpoints();
        self.halt();
    }

    /// Simulate a hard failure ([`crate::container::Container::kill`]):
    /// tear down threads, sockets, and queues like [`Flake::shutdown`]
    /// but **leave the endpoint publication standing** — a crashed
    /// remote process cannot deregister itself.  Senders keep
    /// resolving the dead flake's closed queues and retry until the
    /// repair's replacement republishes over the entry (token-guarded,
    /// so the husk's eventual `shutdown` cannot tear it down).  The
    /// recorded TCP endpoint survives too: it is the husk's record of
    /// having served remote ingress, which repair reads to give the
    /// replacement its own listener.
    pub(crate) fn crash(&self) {
        {
            let mut tcp = self.tcp.lock().expect("tcp state poisoned");
            for rx in tcp.receivers.iter_mut() {
                rx.shutdown();
            }
            tcp.receivers.clear();
        }
        self.halt();
    }

    fn halt(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for q in self.shared.ports.values() {
            q.close();
        }
        self.shared.ready.close();
        if let Some(j) =
            self.dispatcher.lock().expect("dispatcher poisoned").take()
        {
            let _ = j.join();
        }
        self.pool.shutdown();
    }
}

impl Drop for Flake {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn dispatcher_loop(shared: &Shared) {
    let mut windows: BTreeMap<String, (Vec<Message>, Instant)> =
        BTreeMap::new();
    let mut rr_port = 0usize;
    // Fast paths: one interleaved input port — block directly on the
    // queue instead of polling.  Covers the plain and count-window cases.
    let single_port = shared.cfg.merge == MergeMode::Interleaved
        && shared.port_order.len() == 1;
    let single_window = if single_port {
        Some(shared.cfg.inputs[0].window)
    } else {
        None
    };
    let batch_size = shared.cfg.batch_size.max(1);
    let mut batch: Vec<Message> = Vec::new();
    // One pop buffer for the whole dispatcher lifetime: every batched
    // pop drains into this instead of allocating a Vec per batch.
    let mut pop_buf: Vec<Message> = Vec::with_capacity(batch_size);
    let mut idle_polls = 0u32;
    while !shared.stop.load(Ordering::SeqCst) {
        if shared.paused.load(Ordering::SeqCst) {
            // Acknowledge the pause: any batch popped earlier has
            // reached the ready queue by now (see Shared::pause_epoch).
            shared.pause_epoch.fetch_add(1, Ordering::SeqCst);
            thread::sleep(Duration::from_millis(1));
            continue;
        }
        match single_window {
            Some(WindowSpec::None) => {
                // Batched fast path: drain up to batch_size messages
                // per atomic claim (or lock round-trip on the mutex
                // backend) into the reused pop buffer, wrap them, and
                // hand them to the workers in one ready-queue push.
                let port = &shared.port_order[0];
                pop_buf.clear();
                match shared.ports[port].pop_batch_timeout_into(
                    &mut pop_buf,
                    batch_size,
                    Duration::from_millis(10),
                ) {
                    Ok(0) => continue, // timeout
                    Ok(_) => {
                        shared.dedup_filter(port, &mut pop_buf);
                        if pop_buf.is_empty() {
                            continue; // all duplicates
                        }
                        if let Some(tl) = &shared.telemetry {
                            tl.batch.record(pop_buf.len() as u64);
                        }
                        shared.probes.record_arrival(pop_buf.len() as u64);
                        let items: Vec<PortIo> = pop_buf
                            .drain(..)
                            .map(|m| PortIo::Single(port.clone(), m))
                            .collect();
                        if shared.ready.push_batch(items).is_err() {
                            return;
                        }
                    }
                    Err(_) => return, // input closed
                }
                continue;
            }
            Some(WindowSpec::Count(n)) => {
                let port = &shared.port_order[0];
                // Take at most what completes the current window so
                // landmark flushes stay aligned with window boundaries.
                let want = n.saturating_sub(batch.len()).clamp(1, batch_size);
                pop_buf.clear();
                match shared.ports[port].pop_batch_timeout_into(
                    &mut pop_buf,
                    want,
                    Duration::from_millis(10),
                ) {
                    Ok(taken) if taken > 0 => {
                        shared.dedup_filter(port, &mut pop_buf);
                        if pop_buf.is_empty() {
                            continue; // all duplicates
                        }
                        idle_polls = 0;
                        shared
                            .probes
                            .record_arrival(pop_buf.len() as u64);
                        for msg in pop_buf.drain(..) {
                            let flush = msg.is_landmark();
                            batch.push(msg);
                            if batch.len() >= n || flush {
                                let b = std::mem::take(&mut batch);
                                if shared
                                    .ready
                                    .push(PortIo::Window(port.clone(), b))
                                    .is_err()
                                {
                                    return;
                                }
                            }
                        }
                    }
                    Ok(_) => {
                        // Sustained idle: flush a partial batch so tail
                        // messages are not held indefinitely, but give
                        // bursts a few polls to refill the window first
                        // (bigger batches amortize the XLA call).
                        idle_polls += 1;
                        if idle_polls >= 3 && !batch.is_empty() {
                            let b = std::mem::take(&mut batch);
                            if shared
                                .ready
                                .push(PortIo::Window(port.clone(), b))
                                .is_err()
                            {
                                return;
                            }
                        }
                    }
                    Err(_) => {
                        if !batch.is_empty() {
                            let b = std::mem::take(&mut batch);
                            let _ = shared
                                .ready
                                .push(PortIo::Window(port.clone(), b));
                        }
                        return;
                    }
                }
                continue;
            }
            _ => {}
        }
        let made_progress = match shared.cfg.merge {
            MergeMode::Synchronous => dispatch_synchronous(shared),
            MergeMode::Interleaved => dispatch_interleaved(
                shared,
                &mut windows,
                &mut rr_port,
                &mut pop_buf,
            ),
        };
        if !made_progress {
            thread::sleep(Duration::from_micros(200));
            // Flush expired time windows even without new arrivals.
            flush_expired_windows(shared, &mut windows);
        }
    }
}

/// Synchronous merge: form a tuple once every port has a message (P5).
fn dispatch_synchronous(shared: &Shared) -> bool {
    let all_ready = shared
        .port_order
        .iter()
        .all(|p| !shared.ports[p].is_empty());
    if !all_ready {
        return false;
    }
    let mut tuple = BTreeMap::new();
    for p in &shared.port_order {
        match shared.ports[p].try_pop() {
            Some(m) => {
                shared.probes.record_arrival(1);
                tuple.insert(p.clone(), m);
            }
            None => {
                // Lost a race; push back what we took and retry later.
                for (port, msg) in tuple {
                    let _ = shared.ports[&port].push(msg);
                }
                return false;
            }
        }
    }
    shared.ready.push(PortIo::Tuple(tuple)).is_ok()
}

/// Interleaved merge: deliver per-port messages as they arrive, applying
/// window annotations (P3/P6).  Each port is drained in batches of up to
/// `batch_size` per sweep so busy ports pay one lock round-trip per batch
/// without starving the others.
fn dispatch_interleaved(
    shared: &Shared,
    windows: &mut BTreeMap<String, (Vec<Message>, Instant)>,
    rr_port: &mut usize,
    pop_buf: &mut Vec<Message>,
) -> bool {
    let nports = shared.port_order.len();
    if nports == 0 {
        return false;
    }
    let batch_size = shared.cfg.batch_size.max(1);
    let mut progressed = false;
    for k in 0..nports {
        let pi = (*rr_port + k) % nports;
        let port = &shared.port_order[pi];
        pop_buf.clear();
        let taken =
            shared.ports[port].try_pop_batch_into(pop_buf, batch_size);
        if taken == 0 {
            continue;
        }
        progressed = true;
        shared.dedup_filter(port, pop_buf);
        if pop_buf.is_empty() {
            continue; // all duplicates
        }
        shared.probes.record_arrival(pop_buf.len() as u64);
        let spec = shared
            .cfg
            .inputs
            .iter()
            .find(|i| &i.name == port)
            .expect("port spec");
        match spec.window {
            WindowSpec::None => {
                let items: Vec<PortIo> = pop_buf
                    .drain(..)
                    .map(|m| PortIo::Single(port.clone(), m))
                    .collect();
                if shared.ready.push_batch(items).is_err() {
                    return progressed;
                }
            }
            WindowSpec::Count(n) => {
                let entry = windows
                    .entry(port.clone())
                    .or_insert_with(|| (Vec::new(), Instant::now()));
                for msg in pop_buf.drain(..) {
                    // Landmarks flush the window early so reducers see
                    // them.
                    let is_landmark = msg.is_landmark();
                    entry.0.push(msg);
                    if entry.0.len() >= n || is_landmark {
                        let batch = std::mem::take(&mut entry.0);
                        let _ = shared
                            .ready
                            .push(PortIo::Window(port.clone(), batch));
                    }
                }
            }
            WindowSpec::Time(_) => {
                let entry = windows
                    .entry(port.clone())
                    .or_insert_with(|| (Vec::new(), Instant::now()));
                for msg in pop_buf.drain(..) {
                    if entry.0.is_empty() {
                        entry.1 = Instant::now();
                    }
                    entry.0.push(msg);
                }
            }
        }
    }
    *rr_port = (*rr_port + 1) % nports;
    flush_expired_windows(shared, windows);
    progressed
}

fn flush_expired_windows(
    shared: &Shared,
    windows: &mut BTreeMap<String, (Vec<Message>, Instant)>,
) {
    for (port, (buf, started)) in windows.iter_mut() {
        if buf.is_empty() {
            continue;
        }
        let spec = shared
            .cfg
            .inputs
            .iter()
            .find(|i| &i.name == port)
            .expect("port spec");
        if let WindowSpec::Time(secs) = spec.window {
            if started.elapsed().as_secs_f64() >= secs {
                let batch = std::mem::take(buf);
                let _ =
                    shared.ready.push(PortIo::Window(port.clone(), batch));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// Create a fresh pellet instance at the current logic version.
fn make_instance(
    shared: &Shared,
    index: usize,
) -> (u64, Box<dyn Pellet>, PelletContext) {
    let version = shared.version.load(Ordering::SeqCst);
    let factory = shared.factory.read().expect("factory poisoned").clone();
    let mut pellet = factory();
    let mut ctx = PelletContext::new(
        shared.cfg.pellet_id.clone(),
        index,
        version,
        shared.state.clone(),
        Arc::clone(&shared.interrupt),
    );
    if let Err(e) = pellet.setup(&mut ctx) {
        crate::log_error!("pellet {} setup failed: {e}", shared.cfg.pellet_id);
    }
    shared.flush_emissions(&mut ctx);
    (version, pellet, ctx)
}

fn worker_loop(shared: &Shared, index: usize, stop_flag: &AtomicBool) {
    shared.active_instances.fetch_add(1, Ordering::SeqCst);
    let mut instance: Option<(u64, Box<dyn Pellet>, PelletContext)> = None;

    while !stop_flag.load(Ordering::SeqCst)
        && !shared.stop.load(Ordering::SeqCst)
    {
        let version = shared.version.load(Ordering::SeqCst);
        // (Re)create the instance when missing or stale (dynamic update).
        let stale = instance
            .as_ref()
            .map(|(v, _, _)| *v != version)
            .unwrap_or(true);
        if stale {
            if let Some((_, mut old, mut ctx)) = instance.take() {
                old.teardown(&mut ctx);
                shared.flush_emissions(&mut ctx);
            }
            instance = Some(make_instance(shared, index));
        }
        let (ver, pellet, ctx) = instance.as_mut().expect("instance set");
        let version = *ver;

        match shared.cfg.trigger {
            TriggerMode::Push => {
                // Counted pop: the in-flight probe is incremented
                // under the ready-queue lock, closing the window in
                // which a popped item is invisible to quiesce/drain.
                match shared.ready.pop_timeout_counted(
                    Duration::from_millis(20),
                    &shared.probes.inflight,
                ) {
                    Ok(Some(item)) => {
                        // A dynamic update may have landed while this
                        // worker was blocked waiting for the item: a
                        // synchronous update's guarantee is that messages
                        // dispatched after the swap run on the new logic,
                        // so re-check before computing.
                        if shared.version.load(Ordering::SeqCst) != version
                        {
                            if let Some((_, mut old, mut octx)) =
                                instance.take()
                            {
                                old.teardown(&mut octx);
                                shared.flush_emissions(&mut octx);
                            }
                            instance = Some(make_instance(shared, index));
                        }
                        let (_, pellet, ctx) =
                            instance.as_mut().expect("instance set");
                        shared.run_item(pellet, ctx, item);
                    }
                    Ok(None) => {}
                    Err(_) => break, // queue closed
                }
            }
            TriggerMode::Pull => {
                // Feed the pull pellet until it must yield (stop, update,
                // pause).  The source blocks in short slices so the worker
                // can re-check flags, and flushes the pellet's pending
                // emissions on every poll — pull pellets run indefinitely,
                // so output cannot wait for compute_pull to return.
                let emissions = ctx.emission_buffer();
                let mut source = || -> Option<PortIo> {
                    loop {
                        let pending = std::mem::take(
                            &mut *emissions
                                .lock()
                                .expect("emit buffer poisoned"),
                        );
                        if !pending.is_empty() {
                            shared.route_emissions(pending);
                        }
                        if stop_flag.load(Ordering::SeqCst)
                            || shared.stop.load(Ordering::SeqCst)
                            || shared.version.load(Ordering::SeqCst)
                                != version
                            || shared.interrupt.load(Ordering::SeqCst)
                        {
                            return None;
                        }
                        match shared
                            .ready
                            .pop_timeout(Duration::from_millis(20))
                        {
                            Ok(Some(item)) => return Some(item),
                            Ok(None) => continue,
                            Err(_) => return None,
                        }
                    }
                };
                shared.probes.inflight.fetch_add(1, Ordering::SeqCst);
                let start = Instant::now();
                let before =
                    shared.probes.completions.load(Ordering::Relaxed);
                let result = pellet.compute_pull(&mut source, ctx);
                // Pull pellets account their own messages poorly; estimate
                // completions as messages consumed since entry.
                let nanos = start.elapsed().as_nanos() as u64;
                let after =
                    shared.probes.completions.load(Ordering::Relaxed);
                if after == before {
                    // compute_pull consumed without per-item accounting.
                    shared.probes.record_completion(1, nanos.min(1_000_000));
                }
                if let Err(e) = result {
                    crate::log_error!(
                        "pellet {} pull failed: {e}",
                        shared.cfg.pellet_id
                    );
                }
                shared.flush_emissions(ctx);
                shared.probes.inflight.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
    if let Some((_, mut old, mut ctx)) = instance.take() {
        old.teardown(&mut ctx);
        shared.flush_emissions(&mut ctx);
    }
    shared.active_instances.fetch_sub(1, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::InProcTransport;
    use crate::graph::SplitMode;

    fn collect_transport(
    ) -> (Arc<ShardedQueue<Message>>, Arc<dyn Transport>) {
        let q = Arc::new(ShardedQueue::with_default_shards(4096));
        let t: Arc<dyn Transport> = Arc::new(InProcTransport {
            queue: Arc::clone(&q),
            label: "out".into(),
        });
        (q, t)
    }

    fn upper_cfg() -> FlakeConfig {
        FlakeConfig {
            pellet_id: "upper".into(),
            class: "floe.builtin.Uppercase".into(),
            inputs: vec![InPortSpec {
                name: "in".into(),
                window: WindowSpec::None,
            }],
            outputs: vec![OutPortSpec {
                name: "out".into(),
                split: SplitMode::RoundRobin,
            }],
            merge: MergeMode::Interleaved,
            trigger: TriggerMode::Push,
            sequential: false,
            stateful: false,
            cores: 1,
            alpha: 2,
            queue_capacity: 1024,
            batch_size: DEFAULT_BATCH_SIZE,
            input_shards: 2,
            channel_backend: ChannelBackend::default(),
            dedup: false,
        }
    }

    fn upper_factory() -> PelletFactory {
        Arc::new(|| Box::new(crate::pellet::builtins::Uppercase))
    }

    #[test]
    fn push_flake_processes_messages() {
        let flake = Flake::start(upper_cfg(), upper_factory());
        let (outq, t) = collect_transport();
        flake.wire_output("out", t).unwrap();
        for i in 0..50 {
            flake.inject("in", Message::text(format!("m{i}"))).unwrap();
        }
        assert!(flake.drain(Duration::from_secs(5)));
        let mut got = Vec::new();
        while let Some(m) = outq.try_pop() {
            got.push(m.as_text().unwrap().to_string());
        }
        got.sort();
        assert_eq!(got.len(), 50);
        assert!(got.contains(&"M0".to_string()));
        flake.shutdown();
    }

    #[test]
    fn sequential_flake_preserves_order() {
        let mut cfg = upper_cfg();
        cfg.sequential = true;
        let flake = Flake::start(cfg, upper_factory());
        let (outq, t) = collect_transport();
        flake.wire_output("out", t).unwrap();
        for i in 0..100 {
            flake.inject("in", Message::text(format!("{i:03}"))).unwrap();
        }
        assert!(flake.drain(Duration::from_secs(5)));
        let mut got = Vec::new();
        while let Some(m) = outq.try_pop() {
            got.push(m.as_text().unwrap().to_string());
        }
        let want: Vec<String> = (0..100).map(|i| format!("{i:03}")).collect();
        assert_eq!(got, want);
        flake.shutdown();
    }

    #[test]
    fn set_cores_scales_instances() {
        let flake = Flake::start(upper_cfg(), upper_factory());
        assert_eq!(flake.cores(), 1);
        flake.set_cores(3);
        assert_eq!(flake.cores(), 3);
        // alpha=2 -> 6 instances, give workers a moment to spawn
        for _ in 0..100 {
            if flake.instances() == 6 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(flake.instances(), 6);
        flake.shutdown();
    }

    #[test]
    fn serve_tcp_feeds_input_ports() {
        let flake = Flake::start(upper_cfg(), upper_factory());
        let (outq, t) = collect_transport();
        flake.wire_output("out", t).unwrap();
        assert!(!flake.has_tcp_input());
        let ep = flake.serve_tcp(0).unwrap();
        assert!(flake.has_tcp_input());
        // One receiver per flake.
        assert!(flake.serve_tcp(0).is_err());
        let tx = crate::channel::TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("hi")).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some(m) = outq.try_pop() {
                assert_eq!(m.as_text(), Some("HI"));
                break;
            }
            assert!(Instant::now() < deadline, "tcp message never arrived");
            thread::sleep(Duration::from_millis(2));
        }
        flake.shutdown();
        assert!(!flake.has_tcp_input());
    }

    #[test]
    fn dedup_drops_replayed_messages() {
        let mut cfg = upper_cfg();
        cfg.dedup = true;
        cfg.input_shards = 1; // single-producer FIFO: seqs arrive ordered
        cfg.class = "floe.builtin.CountSink".into();
        cfg.outputs.clear();
        let flake = Flake::start(
            cfg,
            Arc::new(|| Box::new(crate::pellet::builtins::CountSink)),
        );
        let msgs: Vec<Message> =
            (0..10).map(|i| Message::text(format!("{i}"))).collect();
        for m in &msgs {
            flake.inject("in", m.clone()).unwrap();
        }
        assert!(flake.drain(Duration::from_secs(5)));
        // At-least-once redelivery: the same messages (same seqs)
        // arrive again and must not double-count.
        for m in &msgs {
            flake.inject("in", m.clone()).unwrap();
        }
        assert!(flake.drain(Duration::from_secs(5)));
        assert_eq!(
            flake.state().get("count"),
            Some(crate::util::json::Json::Num(10.0))
        );
        // Fresh messages (new seqs) still flow.
        flake.inject("in", Message::text("fresh")).unwrap();
        assert!(flake.drain(Duration::from_secs(5)));
        assert_eq!(
            flake.state().get("count"),
            Some(crate::util::json::Json::Num(11.0))
        );
        flake.shutdown();
    }

    #[test]
    fn count_window_batches() {
        let mut cfg = upper_cfg();
        cfg.inputs[0].window = WindowSpec::Count(10);
        cfg.class = "floe.builtin.CountSink".into();
        cfg.outputs.clear();
        let flake = Flake::start(
            cfg,
            Arc::new(|| Box::new(crate::pellet::builtins::CountSink)),
        );
        for i in 0..30 {
            flake.inject("in", Message::text(format!("{i}"))).unwrap();
        }
        assert!(flake.drain(Duration::from_secs(5)));
        assert_eq!(
            flake.state().get("count"),
            Some(crate::util::json::Json::Num(30.0))
        );
        flake.shutdown();
    }
}
