//! Event-driven network I/O core: a readiness-polled multiplexer with
//! a **fixed worker pool**, replacing thread-per-connection ingress.
//!
//! # Why
//!
//! The TCP data plane ([`crate::channel::TcpReceiver`]) and the REST
//! control plane ([`crate::util::http::HttpServer`]) used to burn one
//! blocking OS thread per accepted connection, capping one ingress
//! flake at thousands — not millions — of senders.  This module gives
//! both a shared core whose thread count is bounded by the pool size,
//! not the connection count:
//!
//! * **Poller** — one thread watching every registered socket for
//!   readiness.  On Linux it uses `epoll` (level-triggered +
//!   `EPOLLONESHOT`), declared via direct `extern "C"` bindings so the
//!   crate stays dependency-free; this is deliberately the one
//!   unsafe/libc corner of the codebase.  Everywhere else — or when
//!   `FLOE_NET_POLLER=sweep`, or if `epoll_create1` fails — it falls
//!   back to a portable **rotating nonblocking sweep**: every
//!   registered connection is offered to the pool each round and a
//!   worker's nonblocking read simply returns `WouldBlock` when there
//!   is nothing to do (the same pattern the old accept loops used).
//! * **Workers** — a fixed pool (`FLOE_NET_WORKERS`, default
//!   `max(4, min(cores/2, 8))`) draining a shared ready queue.  Each
//!   connection is a [`Conn`] state machine that owns its socket and
//!   decode buffers; partial frames simply stay buffered in the state
//!   machine between readiness events.
//!
//! # Correctness notes
//!
//! * At most one worker serves a connection at a time: a `queued` flag
//!   claims the slot before it enters the ready queue, and epoll's
//!   `ONESHOT` re-arm happens only after the worker drained the socket
//!   to `WouldBlock` — so per-connection ordering (and therefore
//!   per-producer FIFO on the data plane) is preserved.
//! * Re-arming happens **under the slot's state-machine lock**, the
//!   same lock retirement takes before closing the fd — so a re-arm
//!   can never race a close and poison a recycled fd number.
//! * A state machine that returns [`Serve::Close`] (or whose group is
//!   closed) is retired exactly once: the slot's `Box<dyn Conn>` is
//!   taken under its lock, which drops the socket and (on Linux)
//!   auto-deregisters the fd from epoll.
//! * Workers may block inside a state machine (sink-queue
//!   backpressure, an HTTP handler): that is the same behavior the old
//!   per-connection threads had, but now it occupies one of N workers,
//!   which is why the pool floor is 4.
//!
//! Listeners register with `tick = true`: the poll thread offers them
//! a [`Wake::Tick`] every few milliseconds even when no readiness
//! event fires, which is how idle-teardown deadlines and accept-path
//! housekeeping run without a dedicated timer thread.
//!
//! # Egress (writable-interest) slots
//!
//! [`IoCore::register_writable`] registers a slot whose readiness
//! class is **writability** (`EPOLLOUT` on the epoll backend) instead
//! of readability; its state machine is woken with [`Wake::Writable`].
//! Egress state machines differ from ingress ones in three ways the
//! core supports directly:
//!
//! * They go idle with an *empty* queue rather than an unreadable
//!   socket, so they return [`Serve::Park`] — keep the slot but do
//!   **not** re-arm readiness — and a producer wakes them explicitly
//!   with [`IoCore::kick`].  A `kicked` flag on the slot closes the
//!   race where a kick lands while a worker is mid-serve: the release
//!   point re-enqueues instead of losing the wake.
//! * They replace their socket across reconnects/rebinds, so the
//!   slot's fd is mutable via [`IoCore::update_fd`] (called by the
//!   state machine while it holds the serve claim, which is what makes
//!   the fd swap race-free against re-arms).  `fd = -1` detaches the
//!   slot from the poller entirely; only kicks wake it.
//! * Their deadlines (reconnect backoff, write-stall budgets) are
//!   one-shot and fine-grained, so instead of tickers they schedule a
//!   [`IoCore::kick_in`] timer, serviced by the poll thread at its
//!   normal cadence and served on a *worker* (timers may run blocking
//!   work like `connect`; ticks may not).

use std::collections::HashMap;
use std::sync::atomic::{
    AtomicBool, AtomicI32, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::thread;
use std::time::{Duration, Instant};

use crate::channel::SyncQueue;
use crate::error::{FloeError, Result};

/// Poll-thread cadence: epoll wait timeout / sweep round pause, which
/// also bounds how late a [`Wake::Tick`] can fire.
const POLL_PAUSE: Duration = Duration::from_millis(2);

/// Slow tickers ([`IoCore::register_slow`]) are offered a tick every
/// this many tick rounds — about every 256 ms at the 2 ms pause.
const SLOW_TICK_EVERY: u64 = 128;

/// How long [`IoCore::close_group`] waits for slots claimed by a
/// worker to finish their current serve before giving up (the worker
/// still retires them on release; only the *wait* is bounded).
const CLOSE_WAIT: Duration = Duration::from_secs(2);

/// Max epoll events drained per wait.
#[cfg(target_os = "linux")]
const EVENT_BATCH: usize = 1024;

/// What the core should do with a connection after a wake.
pub enum Serve {
    /// Keep the registration; wake again on the next readiness event.
    Continue,
    /// Keep the registration but do **not** re-arm readiness: the
    /// state machine has no I/O pending (an egress queue ran empty)
    /// and sleeps until an explicit [`IoCore::kick`] /
    /// [`IoCore::kick_in`] — or, on the sweep backend, the next sweep
    /// round offers it anyway.
    Park,
    /// Retire the slot: drop the state machine and close its socket.
    Close,
}

/// Why a state machine is being woken.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wake {
    /// The socket is (probably) readable — drain it to `WouldBlock`.
    Ready,
    /// The socket is (probably) writable — flush queued output to
    /// `WouldBlock`.  Only delivered to slots registered through
    /// [`IoCore::register_writable`]; also what kicks and timers
    /// deliver to such slots (egress machines re-check their own
    /// queue/deadline state on every wake, whatever prompted it).
    Writable,
    /// Periodic housekeeping tick (only for `tick = true` slots).
    Tick,
}

/// A registered connection state machine.  Owns its socket; must use
/// nonblocking reads and return [`Serve::Continue`] on `WouldBlock`,
/// keeping any partial frame buffered for the next wake.
pub trait Conn: Send {
    fn wake(&mut self, wake: Wake, core: &IoCore) -> Serve;
}

/// Which readiness engine drives the core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PollMode {
    /// Linux `epoll` (falls back to `Sweep` off-Linux or on error).
    Epoll,
    /// Portable rotating nonblocking sweep over every registration.
    Sweep,
}

/// One registration: the state machine plus the claim/teardown flags
/// the poller, workers and `close_group` coordinate through.
struct Slot {
    token: u64,
    group: u64,
    /// Raw fd for the epoll backend (unused by the sweep backend and
    /// on non-unix targets, where it is `-1`).  Atomic because egress
    /// slots swap sockets across reconnects ([`IoCore::update_fd`]);
    /// `-1` means "detached from the poller — kicks only".
    fd: AtomicI32,
    /// Readiness class: writability (`Wake::Writable`, egress) instead
    /// of readability (`Wake::Ready`, ingress).
    writable: bool,
    tick: bool,
    /// Slow ticker: offered a `Wake::Tick` only every
    /// [`SLOW_TICK_EVERY`]-th tick round (~every 256 ms), not every
    /// poll pause.  Data-plane connections use this for their idle
    /// deadline: at a thousand connections, fast ticks would cost a
    /// rearm syscall per connection per pause; coarse deadlines don't
    /// need that resolution.
    slow: bool,
    /// Claim flag: set before the slot enters the ready queue (or is
    /// ticked, or retired by `close_group`), cleared by the serving
    /// worker after the socket is drained.  Guarantees single-worker
    /// service and at most one ready-queue entry per slot.
    queued: AtomicBool,
    /// A kick arrived while a worker held the claim: the release
    /// point re-enqueues the slot instead of losing the wake.  Cleared
    /// at serve start, so a kick always yields at least one *full*
    /// serve after it.
    kicked: AtomicBool,
    /// Set by `close_group`; the next release point retires the slot.
    closing: AtomicBool,
    sm: Mutex<Option<Box<dyn Conn>>>,
}

/// The shared event-driven I/O core (see module docs).  One global
/// instance serves every `TcpReceiver` and `HttpServer` in the
/// process; tests may start private cores to pin a poll mode.
pub struct IoCore {
    mode: PollMode,
    #[cfg(target_os = "linux")]
    epoll: Option<epoll::Epoll>,
    registry: Mutex<HashMap<u64, Arc<Slot>>>,
    /// Slots that want periodic `Wake::Tick`s (listeners, HTTP
    /// request deadlines; data connections as slow tickers).
    tickers: Mutex<Vec<Weak<Slot>>>,
    /// One-shot wake timers (`kick_in`): scanned by the poll thread
    /// every round; due entries kick their token.  Unsorted — the list
    /// is small (one entry per egress slot in backoff/stall at most).
    timers: Mutex<Vec<(Instant, u64)>>,
    ready: SyncQueue<Arc<Slot>>,
    next_token: AtomicU64,
    next_group: AtomicU64,
    workers: usize,
    shutdown: AtomicBool,
    serving: AtomicUsize,
}

/// Fixed worker-pool size: `FLOE_NET_WORKERS` when set, else
/// `max(4, min(cores / 2, 8))`.  The floor of 4 keeps one blocked
/// state machine (sink backpressure, a slow REST handler) from
/// starving the rest of the plane.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("FLOE_NET_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            if (1..=256).contains(&n) {
                return n;
            }
        }
    }
    let cores = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(8);
    (cores / 2).clamp(4, 8)
}

fn default_mode() -> PollMode {
    match std::env::var("FLOE_NET_POLLER").as_deref() {
        Ok("sweep") => PollMode::Sweep,
        _ => PollMode::Epoll,
    }
}

/// Raw fd of a socket for the epoll backend.
#[cfg(unix)]
pub fn source_fd<T: std::os::fd::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}

/// Non-unix targets run the sweep backend, which never looks at fds.
#[cfg(not(unix))]
pub fn source_fd<T>(_s: &T) -> i32 {
    -1
}

impl IoCore {
    /// The process-wide core used by `TcpReceiver` and `HttpServer`.
    /// Threads spawn on first use and live for the process.
    pub fn global() -> &'static Arc<IoCore> {
        static CORE: OnceLock<Arc<IoCore>> = OnceLock::new();
        CORE.get_or_init(|| {
            IoCore::start(default_mode(), default_workers())
        })
    }

    /// Start a core with its own poll thread and `workers` workers.
    /// `PollMode::Epoll` silently degrades to the sweep backend when
    /// epoll is unavailable (non-Linux, or `epoll_create1` failed).
    pub fn start(mode: PollMode, workers: usize) -> Arc<IoCore> {
        let workers = workers.max(1);
        #[cfg(target_os = "linux")]
        let (mode, ep) = match mode {
            PollMode::Epoll => match epoll::Epoll::new() {
                Ok(ep) => (PollMode::Epoll, Some(ep)),
                Err(e) => {
                    crate::log_warn!(
                        "netpoll: epoll unavailable ({e}); using the \
                         sweep backend"
                    );
                    (PollMode::Sweep, None)
                }
            },
            PollMode::Sweep => (PollMode::Sweep, None),
        };
        #[cfg(not(target_os = "linux"))]
        let mode = {
            let _ = mode;
            PollMode::Sweep
        };
        let core = Arc::new(IoCore {
            mode,
            #[cfg(target_os = "linux")]
            epoll: ep,
            registry: Mutex::new(HashMap::new()),
            tickers: Mutex::new(Vec::new()),
            timers: Mutex::new(Vec::new()),
            // The `queued` claim flag bounds the queue at one entry
            // per registration, so the capacity is never the limit.
            ready: SyncQueue::new(usize::MAX),
            next_token: AtomicU64::new(1),
            next_group: AtomicU64::new(1),
            workers,
            shutdown: AtomicBool::new(false),
            serving: AtomicUsize::new(0),
        });
        let c = Arc::clone(&core);
        thread::Builder::new()
            .name("floe-net-poll".into())
            .spawn(move || c.poll_loop())
            .expect("spawn net poller");
        for i in 0..workers {
            let c = Arc::clone(&core);
            thread::Builder::new()
                .name(format!("floe-net-w{i}"))
                .spawn(move || c.worker_loop())
                .expect("spawn net worker");
        }
        crate::telemetry::gauge_net_workers().set(workers as u64);
        core
    }

    /// Fixed worker-pool size of this core.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The readiness backend actually in use.
    pub fn mode(&self) -> PollMode {
        self.mode
    }

    /// Currently registered connections (diagnostics / tests).
    pub fn registered(&self) -> usize {
        self.registry.lock().expect("netpoll registry").len()
    }

    /// Allocate a registration group (one per receiver/server, so its
    /// shutdown can retire exactly its own slots).
    pub fn new_group(&self) -> u64 {
        self.next_group.fetch_add(1, Ordering::Relaxed)
    }

    /// Register a nonblocking socket's state machine.  `fd` comes from
    /// [`source_fd`]; `tick` requests periodic [`Wake::Tick`]s.  The
    /// state machine is woken immediately when the socket is already
    /// readable (epoll is level-triggered; the sweep offers every
    /// registration each round).
    pub fn register(
        &self,
        group: u64,
        fd: i32,
        tick: bool,
        sm: Box<dyn Conn>,
    ) -> Result<u64> {
        self.register_opts(group, fd, tick, false, false, sm)
    }

    /// Like [`register`](IoCore::register) with `tick = true`, but the
    /// slot is a *slow* ticker: `Wake::Tick` arrives only every
    /// [`SLOW_TICK_EVERY`]-th tick round.  For coarse per-connection
    /// deadlines (idle/keepalive) on the data plane, where fast ticks
    /// would cost a rearm syscall per connection per poll pause.
    pub fn register_slow(
        &self,
        group: u64,
        fd: i32,
        sm: Box<dyn Conn>,
    ) -> Result<u64> {
        self.register_opts(group, fd, true, true, false, sm)
    }

    /// Register an **egress** state machine: readiness class is
    /// writability and wakes arrive as [`Wake::Writable`].  `fd` may
    /// be `-1` for a not-yet-connected machine — it stays detached
    /// from the poller (only [`kick`](IoCore::kick) /
    /// [`kick_in`](IoCore::kick_in) wake it) until
    /// [`update_fd`](IoCore::update_fd) attaches a socket.
    pub fn register_writable(
        &self,
        group: u64,
        fd: i32,
        sm: Box<dyn Conn>,
    ) -> Result<u64> {
        self.register_opts(group, fd, false, false, true, sm)
    }

    fn register_opts(
        &self,
        group: u64,
        fd: i32,
        tick: bool,
        slow: bool,
        writable: bool,
        sm: Box<dyn Conn>,
    ) -> Result<u64> {
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let slot = Arc::new(Slot {
            token,
            group,
            fd: AtomicI32::new(fd),
            writable,
            tick,
            slow,
            queued: AtomicBool::new(false),
            kicked: AtomicBool::new(false),
            closing: AtomicBool::new(false),
            sm: Mutex::new(Some(sm)),
        });
        let registered = {
            let mut reg =
                self.registry.lock().expect("netpoll registry");
            reg.insert(token, Arc::clone(&slot));
            reg.len()
        };
        if tick {
            self.tickers
                .lock()
                .expect("netpoll tickers")
                .push(Arc::downgrade(&slot));
        }
        #[cfg(target_os = "linux")]
        if fd >= 0 {
            if let Some(ep) = &self.epoll {
                if let Err(e) = ep.add(fd, token, writable) {
                    self.registry
                        .lock()
                        .expect("netpoll registry")
                        .remove(&token);
                    return Err(FloeError::Channel(format!(
                        "netpoll: epoll add failed: {e}"
                    )));
                }
            }
        }
        crate::telemetry::gauge_net_registered()
            .set(registered as u64);
        Ok(token)
    }

    /// Retire every slot in `group`: unclaimed slots are dropped
    /// inline; slots a worker currently holds are flagged and retired
    /// at the worker's release point.  With `wait`, blocks (bounded by
    /// [`CLOSE_WAIT`]) until the claimed ones are gone too, so a
    /// receiver's `shutdown()` returns with no delivery still running.
    pub fn close_group(&self, group: u64, wait: bool) {
        let members: Vec<Arc<Slot>> = self
            .registry
            .lock()
            .expect("netpoll registry")
            .values()
            .filter(|s| s.group == group)
            .cloned()
            .collect();
        for slot in &members {
            slot.closing.store(true, Ordering::SeqCst);
            if slot
                .queued
                .compare_exchange(
                    false,
                    true,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                )
                .is_ok()
            {
                self.retire(slot);
            }
        }
        if !wait {
            return;
        }
        let deadline = Instant::now() + CLOSE_WAIT;
        loop {
            let live = {
                let reg =
                    self.registry.lock().expect("netpoll registry");
                members.iter().any(|s| reg.contains_key(&s.token))
            };
            if !live {
                return;
            }
            if Instant::now() >= deadline {
                crate::log_warn!(
                    "netpoll: close_group({group}) timed out waiting \
                     for in-flight connection(s); they retire on \
                     worker release"
                );
                return;
            }
            thread::sleep(Duration::from_millis(1));
        }
    }

    /// Explicitly wake a slot (producer-side: "the egress queue went
    /// non-empty").  If a worker currently holds the claim, the
    /// `kicked` flag makes its release point re-enqueue the slot, so
    /// the wake is never lost; at most one spurious extra serve can
    /// result, which parked machines shrug off.
    pub fn kick(&self, token: u64) {
        let slot = self
            .registry
            .lock()
            .expect("netpoll registry")
            .get(&token)
            .cloned();
        if let Some(slot) = slot {
            slot.kicked.store(true, Ordering::SeqCst);
            self.enqueue(&slot);
        }
    }

    /// Schedule a one-shot [`kick`](IoCore::kick) after `delay`,
    /// serviced by the poll thread at its normal cadence (so actual
    /// delivery is late by up to [`POLL_PAUSE`]).  Used for reconnect
    /// backoff and write-stall deadlines — the woken machine runs on a
    /// worker, where blocking work is allowed.
    pub fn kick_in(&self, token: u64, delay: Duration) {
        self.timers
            .lock()
            .expect("netpoll timers")
            .push((Instant::now() + delay, token));
    }

    /// Swap the socket behind a slot: store the new fd and (epoll)
    /// register it under the same token with the slot's readiness
    /// class.  `fd = -1` detaches the slot (no poller events; kicks
    /// only).  Must be called by the slot's own state machine while it
    /// is being served — holding the claim is what makes the swap
    /// race-free against re-arms, and the old fd must already be
    /// closed (closing auto-deregisters it from epoll).
    pub fn update_fd(&self, token: u64, fd: i32) -> Result<()> {
        let slot = self
            .registry
            .lock()
            .expect("netpoll registry")
            .get(&token)
            .cloned();
        let Some(slot) = slot else {
            return Ok(());
        };
        slot.fd.store(fd, Ordering::SeqCst);
        #[cfg(target_os = "linux")]
        if fd >= 0 {
            if let Some(ep) = &self.epoll {
                if let Err(e) = ep.add(fd, slot.token, slot.writable) {
                    slot.fd.store(-1, Ordering::SeqCst);
                    return Err(FloeError::Channel(format!(
                        "netpoll: epoll add failed: {e}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Stop a private core's threads (tests).  The global core is
    /// never stopped.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Drop a slot's state machine (closing its socket) exactly once
    /// and remove it from the registry.  Idempotent; callers must hold
    /// the slot's claim.
    fn retire(&self, slot: &Arc<Slot>) {
        let taken =
            slot.sm.lock().expect("netpoll slot").take();
        if taken.is_some() {
            let registered = {
                let mut reg =
                    self.registry.lock().expect("netpoll registry");
                reg.remove(&slot.token);
                reg.len()
            };
            crate::telemetry::gauge_net_registered()
                .set(registered as u64);
        }
        // `taken` drops here, outside both locks: closing the socket
        // (and on Linux auto-deregistering the fd) is the last step.
        drop(taken);
    }

    /// Serve one claimed slot.  The claim (`queued == true`) is ours;
    /// release order matters: clear the claim, then re-arm — both
    /// under the state-machine lock so retirement (which closes the
    /// fd under the same lock) can never interleave with a re-arm.
    fn serve_slot(&self, slot: &Arc<Slot>, wake: Wake) {
        if slot.closing.load(Ordering::SeqCst) {
            self.retire(slot);
            return;
        }
        // Consume any pending kick: this serve sees everything the
        // kicker published before kicking.  A kick landing *during*
        // the serve re-sets the flag and re-enqueues at release.
        slot.kicked.store(false, Ordering::SeqCst);
        let active = self.serving.fetch_add(1, Ordering::Relaxed) + 1;
        crate::telemetry::gauge_net_active().set(active as u64);
        let mut close = false;
        {
            let mut g = slot.sm.lock().expect("netpoll slot");
            // A `None` here means close_group already retired the
            // slot; nothing to serve.
            if let Some(sm) = g.as_mut() {
                match sm.wake(wake, self) {
                    Serve::Continue => {
                        slot.queued.store(false, Ordering::SeqCst);
                        if !slot.closing.load(Ordering::SeqCst) {
                            self.rearm(slot);
                        }
                    }
                    Serve::Park => {
                        // Release the claim without re-arming: the
                        // slot sleeps until a kick (or sweep round).
                        slot.queued.store(false, Ordering::SeqCst);
                    }
                    Serve::Close => close = true,
                }
            }
        }
        let active = self.serving.fetch_sub(1, Ordering::Relaxed) - 1;
        crate::telemetry::gauge_net_active().set(active as u64);
        if close || slot.closing.load(Ordering::SeqCst) {
            self.retire(slot);
        } else if slot.kicked.swap(false, Ordering::SeqCst) {
            // A kick raced this serve; deliver it now.
            self.enqueue(slot);
        }
    }

    #[cfg(target_os = "linux")]
    fn rearm(&self, slot: &Slot) {
        if let Some(ep) = &self.epoll {
            // ENOENT here is benign: the fd raced a retirement.  A
            // recycled fd number is impossible — retirement closes
            // the fd under the same lock this call runs under, and
            // egress machines swap `slot.fd` under that lock too.
            let fd = slot.fd.load(Ordering::SeqCst);
            if fd >= 0 {
                let _ = ep.rearm(fd, slot.token, slot.writable);
            }
        }
    }

    #[cfg(not(target_os = "linux"))]
    fn rearm(&self, _slot: &Slot) {}

    /// Claim `slot` and hand it to the worker pool.
    fn enqueue(&self, slot: &Arc<Slot>) {
        if slot
            .queued
            .compare_exchange(
                false,
                true,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
        {
            let _ = self.ready.push(Arc::clone(slot));
        }
    }

    /// Poll thread: dispatch readiness (epoll) or offer every slot
    /// (sweep), and run ticks, until shutdown.
    fn poll_loop(&self) {
        let mut scan: Vec<Arc<Slot>> = Vec::new();
        #[cfg(target_os = "linux")]
        let mut events: Vec<epoll::Event> =
            Vec::with_capacity(EVENT_BATCH);
        let mut last_tick = Instant::now();
        let mut tick_round: u64 = 0;
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.mode {
                #[cfg(target_os = "linux")]
                PollMode::Epoll => {
                    let ep =
                        self.epoll.as_ref().expect("epoll backend");
                    let n = ep.wait(
                        &mut events,
                        EVENT_BATCH,
                        POLL_PAUSE.as_millis() as i32,
                    );
                    for ev in events.iter().take(n) {
                        let token = ev.token();
                        let slot = self
                            .registry
                            .lock()
                            .expect("netpoll registry")
                            .get(&token)
                            .cloned();
                        if let Some(slot) = slot {
                            self.enqueue(&slot);
                        }
                    }
                }
                #[cfg(not(target_os = "linux"))]
                PollMode::Epoll => unreachable!("epoll off-linux"),
                PollMode::Sweep => {
                    scan.clear();
                    scan.extend(
                        self.registry
                            .lock()
                            .expect("netpoll registry")
                            .values()
                            .cloned(),
                    );
                    for slot in &scan {
                        self.enqueue(slot);
                    }
                    thread::sleep(POLL_PAUSE);
                }
            }
            self.fire_timers();
            if last_tick.elapsed() >= POLL_PAUSE {
                last_tick = Instant::now();
                self.run_ticks(tick_round);
                tick_round = tick_round.wrapping_add(1);
            }
        }
    }

    /// Kick every due `kick_in` timer.  Runs on the poll thread each
    /// round; the kicked machines are served by workers.
    fn fire_timers(&self) {
        let due: Vec<u64> = {
            let mut timers =
                self.timers.lock().expect("netpoll timers");
            if timers.is_empty() {
                return;
            }
            let now = Instant::now();
            let mut due = Vec::new();
            timers.retain(|&(at, token)| {
                if at <= now {
                    due.push(token);
                    false
                } else {
                    true
                }
            });
            due
        };
        for token in due {
            self.kick(token);
        }
    }

    /// Offer a `Wake::Tick` to every live ticker not currently being
    /// served.  Runs on the poll thread; tickers (listeners) must keep
    /// their tick work short.  Slow tickers are offered only every
    /// [`SLOW_TICK_EVERY`]-th round.
    fn run_ticks(&self, round: u64) {
        let slow_due = round % SLOW_TICK_EVERY == 0;
        let mut tickers =
            self.tickers.lock().expect("netpoll tickers");
        tickers.retain(|w| w.strong_count() > 0);
        let live: Vec<Arc<Slot>> =
            tickers.iter().filter_map(Weak::upgrade).collect();
        drop(tickers);
        for slot in live {
            if slot.slow && !slow_due {
                continue;
            }
            if slot
                .queued
                .compare_exchange(
                    false,
                    true,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                )
                .is_ok()
            {
                self.serve_slot(&slot, Wake::Tick);
            }
        }
    }

    fn worker_loop(&self) {
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.ready.pop_timeout(Duration::from_millis(100)) {
                Ok(Some(slot)) => {
                    let wake = if slot.writable {
                        Wake::Writable
                    } else {
                        Wake::Ready
                    };
                    self.serve_slot(&slot, wake)
                }
                Ok(None) => {}       // idle; re-check shutdown
                Err(_) => return,    // queue closed (never happens)
            }
        }
    }
}

/// Linux epoll bindings: the crate's one libc/unsafe corner.  Declared
/// directly (`extern "C"`) because the crate is dependency-free by
/// design; std already links libc on every supported target.
#[cfg(target_os = "linux")]
mod epoll {
    use std::io;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLLONESHOT: u32 = 1 << 30;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// `struct epoll_event`.  Packed on x86-64 only, matching the
    /// kernel/glibc ABI (`__EPOLL_PACKED`).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct Event {
        events: u32,
        data: u64,
    }

    impl Event {
        pub fn token(&self) -> u64 {
            // Field access copies out of the (possibly packed)
            // struct; no reference to the unaligned field is taken.
            self.data
        }
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut Event,
        ) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut Event,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    // The epfd is used from the poll thread (wait) and registering
    // threads (ctl) concurrently; the kernel allows exactly that, so
    // the auto Send/Sync for a plain fd wrapper is sound.
    pub struct Epoll {
        epfd: i32,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll { epfd })
        }

        /// Interest mask for a slot's readiness class: `EPOLLIN` for
        /// ingress, `EPOLLOUT` for egress, both with `EPOLLRDHUP`
        /// (peer shutdown surfaces either way) and one-shot claiming.
        fn interest(writable: bool) -> u32 {
            let class = if writable { EPOLLOUT } else { EPOLLIN };
            class | EPOLLRDHUP | EPOLLONESHOT
        }

        fn ctl(
            &self,
            op: i32,
            fd: i32,
            token: u64,
            events: u32,
        ) -> io::Result<()> {
            let mut ev = Event { events, data: token };
            let rc =
                unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Register interest (level-triggered, one-shot).
        pub fn add(
            &self,
            fd: i32,
            token: u64,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, Self::interest(writable))
        }

        /// Re-arm a one-shot registration after a drain.
        pub fn rearm(
            &self,
            fd: i32,
            token: u64,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, Self::interest(writable))
        }

        /// Wait for events; returns how many landed in `buf`.
        /// `EINTR` and errors report as zero events (the caller loops
        /// on a short timeout anyway).
        pub fn wait(
            &self,
            buf: &mut Vec<Event>,
            max: usize,
            timeout_ms: i32,
        ) -> usize {
            buf.clear();
            buf.reserve(max);
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    buf.as_mut_ptr(),
                    max as i32,
                    timeout_ms,
                )
            };
            if n <= 0 {
                return 0;
            }
            // SAFETY: the kernel initialized the first n events.
            unsafe { buf.set_len(n as usize) };
            n as usize
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                let _ = close(self.epfd);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    /// Counts every byte read; closes on EOF.
    struct CountConn {
        stream: TcpStream,
        total: Arc<AtomicUsize>,
    }

    impl Conn for CountConn {
        fn wake(&mut self, _w: Wake, _core: &IoCore) -> Serve {
            let mut buf = [0u8; 4096];
            loop {
                match self.stream.read(&mut buf) {
                    Ok(0) => return Serve::Close,
                    Ok(n) => {
                        self.total.fetch_add(n, Ordering::SeqCst);
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        return Serve::Continue;
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::Interrupted =>
                    {
                        continue;
                    }
                    Err(_) => return Serve::Close,
                }
            }
        }
    }

    /// Accepts and registers `CountConn`s.
    struct CountListener {
        listener: TcpListener,
        total: Arc<AtomicUsize>,
        group: u64,
        ticks: Arc<AtomicUsize>,
    }

    impl Conn for CountListener {
        fn wake(&mut self, w: Wake, core: &IoCore) -> Serve {
            if w == Wake::Tick {
                self.ticks.fetch_add(1, Ordering::SeqCst);
            }
            loop {
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(true).unwrap();
                        let fd = source_fd(&stream);
                        let conn = CountConn {
                            stream,
                            total: Arc::clone(&self.total),
                        };
                        core.register(
                            self.group,
                            fd,
                            false,
                            Box::new(conn),
                        )
                        .unwrap();
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        return Serve::Continue;
                    }
                    Err(_) => return Serve::Close,
                }
            }
        }
    }

    /// End-to-end on one backend: N clients' bytes all arrive, slots
    /// retire on EOF, close_group empties the registry, ticks fire.
    fn roundtrip_on(mode: PollMode) {
        let core = IoCore::start(mode, 2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let total = Arc::new(AtomicUsize::new(0));
        let ticks = Arc::new(AtomicUsize::new(0));
        let group = core.new_group();
        let fd = source_fd(&listener);
        core.register(
            group,
            fd,
            true,
            Box::new(CountListener {
                listener,
                total: Arc::clone(&total),
                group,
                ticks: Arc::clone(&ticks),
            }),
        )
        .unwrap();

        const CLIENTS: usize = 8;
        const PER: usize = 10_000;
        let mut streams = Vec::new();
        for _ in 0..CLIENTS {
            streams.push(TcpStream::connect(addr).unwrap());
        }
        for s in &mut streams {
            s.write_all(&vec![7u8; PER]).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while total.load(Ordering::SeqCst) < CLIENTS * PER {
            assert!(
                Instant::now() < deadline,
                "bytes missing: {} of {}",
                total.load(Ordering::SeqCst),
                CLIENTS * PER
            );
            thread::sleep(Duration::from_millis(2));
        }
        // EOF retires the data slots.
        drop(streams);
        let deadline = Instant::now() + Duration::from_secs(10);
        while core.registered() > 1 {
            assert!(
                Instant::now() < deadline,
                "conn slots never retired ({})",
                core.registered()
            );
            thread::sleep(Duration::from_millis(2));
        }
        assert!(
            ticks.load(Ordering::SeqCst) > 0,
            "listener never ticked"
        );
        core.close_group(group, true);
        assert_eq!(core.registered(), 0);
        core.stop();
    }

    #[test]
    fn sweep_backend_roundtrip() {
        roundtrip_on(PollMode::Sweep);
    }

    #[test]
    fn epoll_backend_roundtrip() {
        // Off-Linux this degrades to a second sweep run.
        roundtrip_on(PollMode::Epoll);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_mode_actually_selected_on_linux() {
        let core = IoCore::start(PollMode::Epoll, 1);
        assert_eq!(core.mode(), PollMode::Epoll);
        core.stop();
    }

    #[test]
    fn close_group_only_touches_its_own_group() {
        let core = IoCore::start(PollMode::Sweep, 1);
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l2 = TcpListener::bind("127.0.0.1:0").unwrap();
        l1.set_nonblocking(true).unwrap();
        l2.set_nonblocking(true).unwrap();
        let (g1, g2) = (core.new_group(), core.new_group());
        let t = Arc::new(AtomicUsize::new(0));
        let k = Arc::new(AtomicUsize::new(0));
        let fd1 = source_fd(&l1);
        let fd2 = source_fd(&l2);
        core.register(
            g1,
            fd1,
            false,
            Box::new(CountListener {
                listener: l1,
                total: Arc::clone(&t),
                group: g1,
                ticks: Arc::clone(&k),
            }),
        )
        .unwrap();
        core.register(
            g2,
            fd2,
            false,
            Box::new(CountListener {
                listener: l2,
                total: Arc::clone(&t),
                group: g2,
                ticks: Arc::clone(&k),
            }),
        )
        .unwrap();
        assert_eq!(core.registered(), 2);
        core.close_group(g1, true);
        assert_eq!(core.registered(), 1);
        core.close_group(g2, true);
        assert_eq!(core.registered(), 0);
        core.stop();
    }

    /// Egress-style machine: drains a shared byte queue into its
    /// socket on every `Writable` wake, parks when the queue is empty.
    struct QueueTx {
        stream: TcpStream,
        queue: Arc<Mutex<Vec<u8>>>,
        wakes: Arc<AtomicUsize>,
    }

    impl Conn for QueueTx {
        fn wake(&mut self, w: Wake, _core: &IoCore) -> Serve {
            assert_ne!(w, Wake::Ready, "egress slot got a read wake");
            self.wakes.fetch_add(1, Ordering::SeqCst);
            loop {
                let pending = {
                    let mut q = self.queue.lock().unwrap();
                    std::mem::take(&mut *q)
                };
                if pending.is_empty() {
                    return Serve::Park;
                }
                let mut off = 0;
                while off < pending.len() {
                    match self.stream.write(&pending[off..]) {
                        Ok(n) => off += n,
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            // Put the unsent tail back at the front
                            // and wait for writability.
                            let mut q = self.queue.lock().unwrap();
                            let mut rest = pending[off..].to_vec();
                            rest.extend_from_slice(&q);
                            *q = rest;
                            return Serve::Continue;
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::Interrupted =>
                        {
                            continue;
                        }
                        Err(_) => return Serve::Close,
                    }
                }
            }
        }
    }

    /// Writable registration: parked egress slots are woken by kicks
    /// (and timers), drain their queue, and every byte arrives.
    fn egress_kick_on(mode: PollMode) {
        let core = IoCore::start(mode, 2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nonblocking(true).unwrap();
        let (mut peer, _) = listener.accept().unwrap();
        let queue = Arc::new(Mutex::new(Vec::new()));
        let wakes = Arc::new(AtomicUsize::new(0));
        let fd = source_fd(&stream);
        let group = core.new_group();
        let token = core
            .register_writable(
                group,
                fd,
                Box::new(QueueTx {
                    stream,
                    queue: Arc::clone(&queue),
                    wakes: Arc::clone(&wakes),
                }),
            )
            .unwrap();

        const ROUNDS: usize = 50;
        const CHUNK: usize = 1024;
        let reader = thread::spawn(move || {
            let mut buf = vec![0u8; 4096];
            let mut total = 0usize;
            while total < ROUNDS * CHUNK {
                match peer.read(&mut buf) {
                    Ok(0) => break,
                    Ok(n) => total += n,
                    Err(_) => break,
                }
            }
            total
        });
        for _ in 0..ROUNDS {
            queue.lock().unwrap().extend_from_slice(&[9u8; CHUNK]);
            core.kick(token);
        }
        assert_eq!(reader.join().unwrap(), ROUNDS * CHUNK);

        // A timer wake reaches a parked slot too.
        let before = wakes.load(Ordering::SeqCst);
        core.kick_in(token, Duration::from_millis(10));
        let deadline = Instant::now() + Duration::from_secs(5);
        while wakes.load(Ordering::SeqCst) == before {
            assert!(Instant::now() < deadline, "kick_in never fired");
            thread::sleep(Duration::from_millis(2));
        }
        core.close_group(group, true);
        assert_eq!(core.registered(), 0);
        core.stop();
    }

    #[test]
    fn sweep_backend_egress_kick() {
        egress_kick_on(PollMode::Sweep);
    }

    #[test]
    fn epoll_backend_egress_kick() {
        // Off-Linux this degrades to a second sweep run.
        egress_kick_on(PollMode::Epoll);
    }

    #[test]
    fn default_workers_is_bounded() {
        let w = default_workers();
        assert!((1..=256).contains(&w), "{w}");
    }
}
