//! Deterministic, seedable PRNG: SplitMix64 for seeding, xoshiro256** for the
//! stream.  Used by workload generators, the simulator and the property-test
//! harness; determinism makes every experiment replayable from its seed.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection to avoid modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal deviate with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Poisson-distributed count (Knuth for small λ, normal approx above 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.normal_with(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Pick a random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..10).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 10);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 4.0, 30.0, 200.0] {
            let n = 5_000;
            let mean = (0..n).map(|_| r.poisson(lambda)).sum::<u64>() as f64
                / n as f64;
            assert!(
                (mean - lambda).abs() < 0.2 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let mean =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
