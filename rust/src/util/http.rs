//! Minimal HTTP/1.1 server + client over `std::net`.
//!
//! The paper's coordinator, manager, container and flake "expose REST web
//! service endpoints for these management interactions" (§III).  This module
//! is that substrate: a thread-per-connection server dispatching to a handler
//! closure, and a blocking client for control calls.  Bodies are JSON (see
//! [`crate::util::json`]).  Connections are not kept alive — control-plane
//! traffic is low-rate by design.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::error::{FloeError, Result};

/// Parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/flake/pause`.
    pub path: String,
    /// Decoded query parameters.
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query.get(key).map(|s| s.as_str())
    }
}

/// HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn ok_json(body: impl ToString) -> Response {
        Response {
            status: 200,
            content_type: "application/json".into(),
            body: body.to_string().into_bytes(),
        }
    }

    pub fn ok_text(body: impl Into<String>) -> Response {
        Response {
            status: 200,
            content_type: "text/plain".into(),
            body: body.into().into_bytes(),
        }
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain".into(),
            body: msg.into().into_bytes(),
        }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            409 => "Conflict",
            500 => "Internal Server Error",
            _ => "Unknown",
        }
    }
}

/// A running HTTP server; dropping the handle does NOT stop it — call
/// [`HttpServer::shutdown`].
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind to `127.0.0.1:port` (0 picks a free port) and serve requests on a
    /// background thread via `handler`.
    pub fn start<F>(port: u16, handler: F) -> Result<HttpServer>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler = Arc::new(handler);
        let join = thread::Builder::new()
            .name(format!("http-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            thread::spawn(move || {
                                let _ = serve_connection(stream, &*h);
                            });
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn http thread");
        Ok(HttpServer { addr, stop, join: Some(join) })
    }

    /// `host:port` this server is bound to.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn serve_connection<F>(mut stream: TcpStream, handler: &F) -> Result<()>
where
    F: Fn(&Request) -> Response,
{
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let resp = Response::error(400, format!("bad request: {e}"));
            write_response(&mut stream, &resp)?;
            return Ok(());
        }
    };
    let resp = handler(&req);
    write_response(&mut stream, &resp)
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| FloeError::Parse("http: empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| FloeError::Parse("http: missing target".into()))?
        .to_string();
    let (path, query) = split_target(&target);

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(
                k.trim().to_ascii_lowercase(),
                v.trim().to_string(),
            );
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request { method, path, query, headers, body })
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), BTreeMap::new()),
        Some((p, q)) => {
            let mut query = BTreeMap::new();
            for pair in q.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                query.insert(url_decode(k), url_decode(v));
            }
            (p.to_string(), query)
        }
    }
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() + 1 => {
                let hex = std::str::from_utf8(&bytes[i + 1..(i + 3).min(bytes.len())])
                    .ok()
                    .and_then(|h| u8::from_str_radix(h, 16).ok());
                match hex {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.status_text(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Blocking HTTP client call. `addr` is `host:port`; returns (status, body).
pub fn http_call(
    method: &str,
    addr: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            FloeError::Parse(format!("http: bad status line {status_line:?}"))
        })?;
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((status, body))
}

/// GET helper returning the body as a string; errors on non-2xx.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let (status, body) = http_call("GET", addr, path, &[])?;
    if !(200..300).contains(&status) {
        return Err(FloeError::Control(format!(
            "GET {path} -> {status}: {}",
            String::from_utf8_lossy(&body)
        )));
    }
    Ok(String::from_utf8_lossy(&body).into_owned())
}

/// POST helper with a JSON/text body; errors on non-2xx.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<String> {
    let (status, resp) = http_call("POST", addr, path, body.as_bytes())?;
    if !(200..300).contains(&status) {
        return Err(FloeError::Control(format!(
            "POST {path} -> {status}: {}",
            String::from_utf8_lossy(&resp)
        )));
    }
    Ok(String::from_utf8_lossy(&resp).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_roundtrip() {
        let mut srv = HttpServer::start(0, |req| {
            assert_eq!(req.method, "GET");
            Response::ok_text(format!("path={}", req.path))
        })
        .unwrap();
        let body = http_get(&srv.addr(), "/status").unwrap();
        assert_eq!(body, "path=/status");
        srv.shutdown();
    }

    #[test]
    fn post_with_body_and_query() {
        let mut srv = HttpServer::start(0, |req| {
            let who = req.query_get("who").unwrap_or("?").to_string();
            Response::ok_json(format!(
                "{{\"who\":\"{who}\",\"len\":{}}}",
                req.body.len()
            ))
        })
        .unwrap();
        let body =
            http_post(&srv.addr(), "/hello?who=floe%20x&v=1", "0123456789")
                .unwrap();
        assert!(body.contains("\"who\":\"floe x\""), "{body}");
        assert!(body.contains("\"len\":10"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn non_2xx_is_error() {
        let mut srv = HttpServer::start(0, |_req| {
            Response::error(404, "nope")
        })
        .unwrap();
        let err = http_get(&srv.addr(), "/missing").unwrap_err();
        assert!(err.to_string().contains("404"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let mut srv = HttpServer::start(0, |req| {
            Response::ok_text(req.path.clone())
        })
        .unwrap();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    http_get(&a, &format!("/r{i}")).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), format!("/r{i}"));
        }
        srv.shutdown();
    }

    #[test]
    fn url_decode_cases() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("plain"), "plain");
        assert_eq!(url_decode("bad%zz"), "bad%zz".replace("%zz", "%zz"));
    }
}
