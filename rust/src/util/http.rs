//! Minimal HTTP/1.1 server + client over `std::net`.
//!
//! The paper's coordinator, manager, container and flake "expose REST web
//! service endpoints for these management interactions" (§III).  This module
//! is that substrate: a server dispatching to a handler closure, and a
//! blocking client for control calls.  Bodies are JSON (see
//! [`crate::util::json`]).  Connections are not kept alive — control-plane
//! traffic is low-rate by design.
//!
//! The server runs on the process-wide event-driven I/O core
//! ([`IoCore::global`]): the listener and every in-flight request are
//! state machines on the shared worker pool, so a scraped `/metrics`
//! plane costs zero dedicated threads instead of one per request.
//!
//! Peer input is bounded everywhere it is read: header block and
//! per-line size, header count and declared body length are all capped
//! (431/413 server-side, [`FloeError::Parse`] client-side), so a
//! misbehaving peer cannot OOM the coordinator by claiming a huge
//! `Content-Length` or streaming an endless header line.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{FloeError, Result};
use crate::util::netpoll::{source_fd, Conn, IoCore, Serve, Wake};

/// Cap on the request/response head (request line + all headers).
const MAX_HEAD_BYTES: usize = 16 << 10;

/// Cap on one header line (client-side line reads).
const MAX_HEAD_LINE: usize = 8 << 10;

/// Cap on the number of headers, both directions.
const MAX_HEADERS: usize = 64;

/// Cap on a request body the server will buffer (413 beyond).
const MAX_BODY: usize = 4 << 20;

/// Cap on a response body the client will buffer.
const MAX_CLIENT_BODY: usize = 16 << 20;

/// How long a connection may take to deliver its request and accept
/// the response before the server hangs up on it.
const REQUEST_DEADLINE: Duration = Duration::from_secs(10);

/// Parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/flake/pause`.
    pub path: String,
    /// Decoded query parameters.
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query.get(key).map(|s| s.as_str())
    }
}

/// HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn ok_json(body: impl ToString) -> Response {
        Response {
            status: 200,
            content_type: "application/json".into(),
            body: body.to_string().into_bytes(),
        }
    }

    pub fn ok_text(body: impl Into<String>) -> Response {
        Response {
            status: 200,
            content_type: "text/plain".into(),
            body: body.into().into_bytes(),
        }
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain".into(),
            body: msg.into().into_bytes(),
        }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            404 => "Not Found",
            409 => "Conflict",
            413 => "Payload Too Large",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            _ => "Unknown",
        }
    }
}

/// A running HTTP server on the shared I/O core.  Dropping the handle
/// stops accepting; [`HttpServer::shutdown`] additionally waits for
/// in-flight requests to retire.
pub struct HttpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    core: Arc<IoCore>,
    group: u64,
}

impl HttpServer {
    /// Bind to `127.0.0.1:port` (0 picks a free port) and serve
    /// requests through `handler` on the process-wide I/O core.
    pub fn start<F>(port: u16, handler: F) -> Result<HttpServer>
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let core = Arc::clone(IoCore::global());
        let group = core.new_group();
        let fd = source_fd(&listener);
        let sm = HttpListener {
            listener,
            handler: Arc::new(handler),
            stop: Arc::clone(&stop),
            group,
        };
        core.register(group, fd, false, Box::new(sm))?;
        Ok(HttpServer { addr, stop, core, group })
    }

    /// `host:port` this server is bound to.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Stop accepting and wait (bounded) for in-flight requests.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.core.close_group(self.group, true);
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.core.close_group(self.group, false);
    }
}

/// Accepts connections and registers one [`HttpConn`] per request.
struct HttpListener<F> {
    listener: TcpListener,
    handler: Arc<F>,
    stop: Arc<AtomicBool>,
    group: u64,
}

impl<F> Conn for HttpListener<F>
where
    F: Fn(&Request) -> Response + Send + Sync + 'static,
{
    fn wake(&mut self, _w: Wake, core: &IoCore) -> Serve {
        if self.stop.load(Ordering::SeqCst) {
            return Serve::Close;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let fd = source_fd(&stream);
                    let conn = HttpConn {
                        stream,
                        handler: Arc::clone(&self.handler),
                        buf: Vec::new(),
                        deadline: Instant::now() + REQUEST_DEADLINE,
                    };
                    // tick = true: the poller's ticks enforce the
                    // request deadline on stalled clients.
                    let _ = core.register(
                        self.group,
                        fd,
                        true,
                        Box::new(conn),
                    );
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Serve::Continue;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => return Serve::Close,
            }
        }
    }
}

/// One in-flight request: buffers incrementally across readiness
/// events, serves the handler once the (capped) head and body are
/// complete, writes the response and closes.
struct HttpConn<F> {
    stream: TcpStream,
    handler: Arc<F>,
    buf: Vec<u8>,
    deadline: Instant,
}

impl<F> HttpConn<F>
where
    F: Fn(&Request) -> Response,
{
    /// Write `resp` and end the connection.  The write flips back to
    /// blocking with a timeout: responses are small and the request
    /// is already over, so occupying the worker briefly is fine.
    fn respond(&mut self, resp: &Response) -> Serve {
        let _ = self.stream.set_nonblocking(false);
        let _ = self
            .stream
            .set_write_timeout(Some(REQUEST_DEADLINE));
        let _ = write_response(&mut self.stream, resp);
        Serve::Close
    }

    /// Try to serve what is buffered so far.  `None` means the
    /// request is still incomplete (within its caps) — keep reading.
    fn try_serve(&mut self) -> Option<Serve> {
        let head_end = self
            .buf
            .windows(4)
            .position(|w| w == b"\r\n\r\n");
        let Some(head_end) = head_end else {
            if self.buf.len() > MAX_HEAD_BYTES {
                return Some(self.respond(&Response::error(
                    431,
                    "header block too large",
                )));
            }
            return None;
        };
        if head_end > MAX_HEAD_BYTES {
            return Some(self.respond(&Response::error(
                431,
                "header block too large",
            )));
        }
        let head =
            String::from_utf8_lossy(&self.buf[..head_end])
                .into_owned();
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let Some(method) = parts.next() else {
            return Some(self.respond(&Response::error(
                400,
                "bad request: empty request line",
            )));
        };
        let Some(target) = parts.next() else {
            return Some(self.respond(&Response::error(
                400,
                "bad request: missing target",
            )));
        };
        let mut headers = BTreeMap::new();
        for line in lines {
            if headers.len() >= MAX_HEADERS {
                return Some(self.respond(&Response::error(
                    431,
                    "too many headers",
                )));
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.insert(
                    k.trim().to_ascii_lowercase(),
                    v.trim().to_string(),
                );
            }
        }
        let len: usize = headers
            .get("content-length")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        if len > MAX_BODY {
            // Rejected from the *declared* length — the body is
            // never buffered, let alone allocated up front.
            return Some(self.respond(&Response::error(
                413,
                format!("body exceeds {MAX_BODY} bytes"),
            )));
        }
        let body_start = head_end + 4;
        if self.buf.len() < body_start + len {
            return None; // body still arriving (bounded by the cap)
        }
        let (path, query) = split_target(target);
        let req = Request {
            method: method.to_string(),
            path,
            query,
            headers,
            body: self.buf[body_start..body_start + len].to_vec(),
        };
        let resp = (self.handler)(&req);
        Some(self.respond(&resp))
    }
}

impl<F> Conn for HttpConn<F>
where
    F: Fn(&Request) -> Response + Send + Sync + 'static,
{
    fn wake(&mut self, w: Wake, _core: &IoCore) -> Serve {
        if w == Wake::Tick {
            if Instant::now() >= self.deadline {
                return Serve::Close; // stalled client: hang up
            }
            return Serve::Continue;
        }
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => return Serve::Close, // EOF before complete
                Ok(n) => {
                    self.buf.extend_from_slice(&chunk[..n]);
                    if let Some(s) = self.try_serve() {
                        return s;
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Serve::Continue;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => return Serve::Close,
            }
        }
    }
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    match target.split_once('?') {
        None => (target.to_string(), BTreeMap::new()),
        Some((p, q)) => {
            let mut query = BTreeMap::new();
            for pair in q.split('&') {
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
                query.insert(url_decode(k), url_decode(v));
            }
            (p.to_string(), query)
        }
    }
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            // A percent escape needs two hex digits after it; a
            // truncated trailing escape ("%" or "%2") passes through
            // literally instead of mis-decoding.
            b'%' if i + 3 <= bytes.len() => {
                let hi = (bytes[i + 1] as char).to_digit(16);
                let lo = (bytes[i + 2] as char).to_digit(16);
                match (hi, lo) {
                    (Some(h), Some(l)) => {
                        out.push((h * 16 + l) as u8);
                        i += 3;
                    }
                    _ => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.status_text(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Read one header line, erroring instead of buffering without bound
/// when the peer never sends a newline.
fn read_line_capped(
    reader: &mut BufReader<TcpStream>,
    cap: usize,
) -> Result<String> {
    let mut line = String::new();
    let n = reader
        .by_ref()
        .take(cap as u64)
        .read_line(&mut line)?;
    if n >= cap && !line.ends_with('\n') {
        return Err(FloeError::Parse(format!(
            "http: header line exceeds {cap} bytes"
        )));
    }
    Ok(line)
}

/// Blocking HTTP client call. `addr` is `host:port`; returns (status, body).
pub fn http_call(
    method: &str,
    addr: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let status_line =
        read_line_capped(&mut reader, MAX_HEAD_LINE)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            FloeError::Parse(format!("http: bad status line {status_line:?}"))
        })?;
    let mut len = 0usize;
    let mut header_count = 0usize;
    loop {
        let h = read_line_capped(&mut reader, MAX_HEAD_LINE)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        header_count += 1;
        if header_count > MAX_HEADERS {
            return Err(FloeError::Parse(format!(
                "http: more than {MAX_HEADERS} response headers"
            )));
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    // Bound the allocation by the *cap*, not the peer's claim.
    if len > MAX_CLIENT_BODY {
        return Err(FloeError::Parse(format!(
            "http: response body {len} exceeds {MAX_CLIENT_BODY} bytes"
        )));
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((status, body))
}

/// GET helper returning the body as a string; errors on non-2xx.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let (status, body) = http_call("GET", addr, path, &[])?;
    if !(200..300).contains(&status) {
        return Err(FloeError::Control(format!(
            "GET {path} -> {status}: {}",
            String::from_utf8_lossy(&body)
        )));
    }
    Ok(String::from_utf8_lossy(&body).into_owned())
}

/// POST helper with a JSON/text body; errors on non-2xx.
pub fn http_post(addr: &str, path: &str, body: &str) -> Result<String> {
    let (status, resp) = http_call("POST", addr, path, body.as_bytes())?;
    if !(200..300).contains(&status) {
        return Err(FloeError::Control(format!(
            "POST {path} -> {status}: {}",
            String::from_utf8_lossy(&resp)
        )));
    }
    Ok(String::from_utf8_lossy(&resp).into_owned())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Shutdown;

    #[test]
    fn get_roundtrip() {
        let mut srv = HttpServer::start(0, |req| {
            assert_eq!(req.method, "GET");
            Response::ok_text(format!("path={}", req.path))
        })
        .unwrap();
        let body = http_get(&srv.addr(), "/status").unwrap();
        assert_eq!(body, "path=/status");
        srv.shutdown();
    }

    #[test]
    fn post_with_body_and_query() {
        let mut srv = HttpServer::start(0, |req| {
            let who = req.query_get("who").unwrap_or("?").to_string();
            Response::ok_json(format!(
                "{{\"who\":\"{who}\",\"len\":{}}}",
                req.body.len()
            ))
        })
        .unwrap();
        let body =
            http_post(&srv.addr(), "/hello?who=floe%20x&v=1", "0123456789")
                .unwrap();
        assert!(body.contains("\"who\":\"floe x\""), "{body}");
        assert!(body.contains("\"len\":10"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn non_2xx_is_error() {
        let mut srv = HttpServer::start(0, |_req| {
            Response::error(404, "nope")
        })
        .unwrap();
        let err = http_get(&srv.addr(), "/missing").unwrap_err();
        assert!(err.to_string().contains("404"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let mut srv = HttpServer::start(0, |req| {
            Response::ok_text(req.path.clone())
        })
        .unwrap();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    http_get(&a, &format!("/r{i}")).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), format!("/r{i}"));
        }
        srv.shutdown();
    }

    /// Write raw bytes, read the whole (close-delimited) response.
    fn raw_call(addr: &str, req: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(req).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        String::from_utf8_lossy(&buf).into_owned()
    }

    /// A request arriving in many small packets (head split mid-line,
    /// body split) is reassembled across readiness events.
    #[test]
    fn request_split_across_packets_is_served() {
        let mut srv = HttpServer::start(0, |req| {
            Response::ok_text(format!("got:{}", req.body_str()))
        })
        .unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let req =
            b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        for piece in req.chunks(7) {
            s.write_all(piece).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let resp = String::from_utf8_lossy(&buf);
        assert!(resp.contains("got:hello"), "{resp}");
        srv.shutdown();
    }

    /// An endless header line (no newline, no head terminator) is cut
    /// off with 431 instead of buffering without bound.
    #[test]
    fn oversized_header_line_rejected_431() {
        let mut srv =
            HttpServer::start(0, |_req| Response::ok_text("?"))
                .unwrap();
        let mut req = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        req.extend(vec![b'a'; MAX_HEAD_BYTES + 1]);
        let resp = raw_call(&srv.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "{resp}");
        srv.shutdown();
    }

    /// More headers than the cap → 431.
    #[test]
    fn too_many_headers_rejected_431() {
        let mut srv =
            HttpServer::start(0, |_req| Response::ok_text("?"))
                .unwrap();
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 8) {
            req.extend(format!("X-H{i}: v\r\n").into_bytes());
        }
        req.extend(b"\r\n");
        let resp = raw_call(&srv.addr(), &req);
        assert!(resp.starts_with("HTTP/1.1 431"), "{resp}");
        srv.shutdown();
    }

    /// A huge declared Content-Length is rejected with 413 up front —
    /// nothing is allocated from the peer's claim.
    #[test]
    fn oversized_body_rejected_413() {
        let mut srv =
            HttpServer::start(0, |_req| Response::ok_text("?"))
                .unwrap();
        let req = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        );
        let resp = raw_call(&srv.addr(), req.as_bytes());
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        srv.shutdown();
    }

    /// The client refuses to allocate a response body bigger than its
    /// cap, failing with a parse error instead of trusting the peer.
    #[test]
    fn client_rejects_oversized_response_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Drain the request head, then claim a giant body.
            let mut buf = [0u8; 1024];
            let _ = s.read(&mut buf);
            let _ = s.write_all(
                b"HTTP/1.1 200 OK\r\nContent-Length: 999999999\r\n\r\n",
            );
            let _ = s.shutdown(Shutdown::Write);
        });
        let err = http_call("GET", &addr, "/", &[]).unwrap_err();
        assert!(
            matches!(err, FloeError::Parse(_)),
            "want Parse error, got {err}"
        );
        server.join().unwrap();
    }

    #[test]
    fn url_decode_cases() {
        assert_eq!(url_decode("a%20b+c"), "a b c");
        assert_eq!(url_decode("plain"), "plain");
        // Truncated or malformed escapes pass through literally —
        // "%2" used to mis-decode into byte 0x02.
        assert_eq!(url_decode("trail%"), "trail%");
        assert_eq!(url_decode("trail%2"), "trail%2");
        assert_eq!(url_decode("bad%zz"), "bad%zz");
        assert_eq!(url_decode("%%20"), "% ");
        assert_eq!(url_decode("%2+"), "%2 ");
    }
}
