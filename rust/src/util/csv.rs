//! Tiny CSV reader/writer (RFC-4180 quoting) for simulator time-series
//! output and the Smart Grid bulk meter archives.

use std::io::{BufRead, Write};

use crate::error::Result;

/// Write one CSV record, quoting fields that need it.
pub fn write_record<W: Write>(w: &mut W, fields: &[&str]) -> Result<()> {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            w.write_all(b",")?;
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            w.write_all(b"\"")?;
            w.write_all(f.replace('"', "\"\"").as_bytes())?;
            w.write_all(b"\"")?;
        } else {
            w.write_all(f.as_bytes())?;
        }
    }
    w.write_all(b"\n")?;
    Ok(())
}

/// Parse one CSV line into fields (handles quoted fields with embedded
/// commas/quotes; embedded newlines must already be joined by the caller).
pub fn parse_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    fields.push(std::mem::take(&mut cur));
                }
                '\r' => {}
                c => cur.push(c),
            }
        }
    }
    fields.push(cur);
    fields
}

/// Read all records from a reader, skipping blank lines.
pub fn read_all<R: BufRead>(r: R) -> Result<Vec<Vec<String>>> {
    let mut out = Vec::new();
    for line in r.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(parse_line(&line));
    }
    Ok(out)
}

/// Convenience: a growable in-memory CSV table with a header row.
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        debug_assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    /// Serialize to CSV text.
    pub fn to_csv(&self) -> String {
        let mut buf = Vec::new();
        let hdr: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        write_record(&mut buf, &hdr).expect("vec write");
        for row in &self.rows {
            let fields: Vec<&str> = row.iter().map(|s| s.as_str()).collect();
            write_record(&mut buf, &fields).expect("vec write");
        }
        String::from_utf8(buf).expect("csv is utf8")
    }

    /// Write to a file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let mut buf = Vec::new();
        write_record(&mut buf, &["a", "b", "c"]).unwrap();
        let line = String::from_utf8(buf).unwrap();
        assert_eq!(parse_line(line.trim_end()), vec!["a", "b", "c"]);
    }

    #[test]
    fn roundtrip_quoted() {
        let mut buf = Vec::new();
        write_record(&mut buf, &["a,b", "say \"hi\"", "plain"]).unwrap();
        let line = String::from_utf8(buf).unwrap();
        assert_eq!(
            parse_line(line.trim_end()),
            vec!["a,b", "say \"hi\"", "plain"]
        );
    }

    #[test]
    fn read_all_skips_blank() {
        let data = "a,b\n\n1,2\r\n3,4\n";
        let rows = read_all(std::io::Cursor::new(data)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1], vec!["1", "2"]);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = CsvTable::new(&["t", "cores"]);
        t.push(vec!["0.5".into(), "4".into()]);
        t.push(vec!["1.0".into(), "6".into()]);
        let text = t.to_csv();
        let rows = read_all(std::io::Cursor::new(text)).unwrap();
        assert_eq!(rows[0], vec!["t", "cores"]);
        assert_eq!(rows[2], vec!["1.0", "6"]);
    }

    #[test]
    fn empty_fields() {
        assert_eq!(parse_line("a,,c"), vec!["a", "", "c"]);
        assert_eq!(parse_line(""), vec![""]);
    }
}
