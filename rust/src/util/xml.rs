//! Minimal XML parser for Floe graph descriptions (§III: "applications are
//! composed as a directed graph, described in XML") and NOAA-style weather
//! documents in the Smart Grid pipeline.
//!
//! Supports elements, attributes (single/double quoted), text content, the
//! five predefined entities, numeric character references, comments, CDATA,
//! processing instructions and the XML declaration.  No DTDs or namespaces —
//! our documents don't use them.

use crate::error::{FloeError, Result};

/// An XML element node.
#[derive(Debug, Clone, PartialEq)]
pub struct XmlNode {
    pub name: String,
    pub attrs: Vec<(String, String)>,
    pub children: Vec<XmlNode>,
    /// Concatenated text content directly under this element (trimmed).
    pub text: String,
}

impl XmlNode {
    /// Attribute lookup.
    pub fn attr(&self, name: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Attribute lookup with a graph-level error on absence.
    pub fn req_attr(&self, name: &str) -> Result<&str> {
        self.attr(name).ok_or_else(|| {
            FloeError::Parse(format!(
                "xml: <{}> missing required attribute '{name}'",
                self.name
            ))
        })
    }

    /// First child element with the given tag name.
    pub fn child(&self, name: &str) -> Option<&XmlNode> {
        self.children.iter().find(|c| c.name == name)
    }

    /// All child elements with the given tag name.
    pub fn children_named<'a>(
        &'a self,
        name: &'a str,
    ) -> impl Iterator<Item = &'a XmlNode> {
        self.children.iter().filter(move |c| c.name == name)
    }

    /// Parse a document, returning the root element.
    pub fn parse(text: &str) -> Result<XmlNode> {
        let mut p = XmlParser { b: text.as_bytes(), pos: 0 };
        p.skip_misc();
        let root = p.element()?;
        p.skip_misc();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content after root element"));
        }
        Ok(root)
    }

    /// Serialize back to XML text (used by graph round-trip tests).
    pub fn to_xml(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        out.push('<');
        out.push_str(&self.name);
        for (k, v) in &self.attrs {
            out.push(' ');
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(&escape(v));
            out.push('"');
        }
        if self.children.is_empty() && self.text.is_empty() {
            out.push_str("/>");
            return;
        }
        out.push('>');
        out.push_str(&escape(&self.text));
        for c in &self.children {
            c.write(out);
        }
        out.push_str("</");
        out.push_str(&self.name);
        out.push('>');
    }
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

struct XmlParser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> XmlParser<'a> {
    fn err(&self, msg: &str) -> FloeError {
        FloeError::Parse(format!("xml: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn starts_with(&self, s: &str) -> bool {
        self.b[self.pos..].starts_with(s.as_bytes())
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    /// Skip whitespace, comments, PIs and the XML declaration.
    fn skip_misc(&mut self) {
        loop {
            self.skip_ws();
            if self.starts_with("<!--") {
                match find(self.b, self.pos + 4, "-->") {
                    Some(end) => self.pos = end + 3,
                    None => {
                        self.pos = self.b.len();
                        return;
                    }
                }
            } else if self.starts_with("<?") {
                match find(self.b, self.pos + 2, "?>") {
                    Some(end) => self.pos = end + 2,
                    None => {
                        self.pos = self.b.len();
                        return;
                    }
                }
            } else if self.starts_with("<!DOCTYPE") {
                while let Some(c) = self.peek() {
                    self.pos += 1;
                    if c == b'>' {
                        break;
                    }
                }
            } else {
                return;
            }
        }
    }

    fn name(&mut self) -> Result<String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            let ch = c as char;
            if ch.is_alphanumeric() || matches!(ch, '_' | '-' | '.' | ':') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if self.pos == start {
            return Err(self.err("expected name"));
        }
        Ok(String::from_utf8_lossy(&self.b[start..self.pos]).into_owned())
    }

    fn element(&mut self) -> Result<XmlNode> {
        if self.peek() != Some(b'<') {
            return Err(self.err("expected '<'"));
        }
        self.pos += 1;
        let name = self.name()?;
        let mut node = XmlNode {
            name,
            attrs: Vec::new(),
            children: Vec::new(),
            text: String::new(),
        };
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'/') => {
                    self.pos += 1;
                    if self.peek() != Some(b'>') {
                        return Err(self.err("expected '>' after '/'"));
                    }
                    self.pos += 1;
                    return Ok(node); // self-closing
                }
                Some(b'>') => {
                    self.pos += 1;
                    break;
                }
                Some(_) => {
                    let k = self.name()?;
                    self.skip_ws();
                    if self.peek() != Some(b'=') {
                        return Err(self.err("expected '=' in attribute"));
                    }
                    self.pos += 1;
                    self.skip_ws();
                    let quote = self
                        .peek()
                        .filter(|&q| q == b'"' || q == b'\'')
                        .ok_or_else(|| self.err("expected quoted value"))?;
                    self.pos += 1;
                    let start = self.pos;
                    while self.peek() != Some(quote) {
                        if self.peek().is_none() {
                            return Err(self.err("unterminated attribute"));
                        }
                        self.pos += 1;
                    }
                    let raw = String::from_utf8_lossy(
                        &self.b[start..self.pos],
                    )
                    .into_owned();
                    self.pos += 1;
                    node.attrs.push((k, unescape(&raw)?));
                }
                None => return Err(self.err("unterminated start tag")),
            }
        }
        // Content: text, children, comments, CDATA until end tag.
        loop {
            if self.starts_with("</") {
                self.pos += 2;
                let end = self.name()?;
                if end != node.name {
                    return Err(self.err(&format!(
                        "mismatched end tag </{end}> for <{}>",
                        node.name
                    )));
                }
                self.skip_ws();
                if self.peek() != Some(b'>') {
                    return Err(self.err("expected '>' in end tag"));
                }
                self.pos += 1;
                node.text = node.text.trim().to_string();
                return Ok(node);
            } else if self.starts_with("<!--") {
                let end = find(self.b, self.pos + 4, "-->")
                    .ok_or_else(|| self.err("unterminated comment"))?;
                self.pos = end + 3;
            } else if self.starts_with("<![CDATA[") {
                let end = find(self.b, self.pos + 9, "]]>")
                    .ok_or_else(|| self.err("unterminated CDATA"))?;
                node.text.push_str(&String::from_utf8_lossy(
                    &self.b[self.pos + 9..end],
                ));
                self.pos = end + 3;
            } else if self.starts_with("<?") {
                let end = find(self.b, self.pos + 2, "?>")
                    .ok_or_else(|| self.err("unterminated PI"))?;
                self.pos = end + 2;
            } else if self.peek() == Some(b'<') {
                node.children.push(self.element()?);
            } else if self.peek().is_none() {
                return Err(self.err(&format!(
                    "unterminated element <{}>",
                    node.name
                )));
            } else {
                let start = self.pos;
                while self.peek().is_some() && self.peek() != Some(b'<') {
                    self.pos += 1;
                }
                let raw =
                    String::from_utf8_lossy(&self.b[start..self.pos])
                        .into_owned();
                node.text.push_str(&unescape(&raw)?);
            }
        }
    }
}

fn find(haystack: &[u8], from: usize, needle: &str) -> Option<usize> {
    let n = needle.as_bytes();
    if from >= haystack.len() {
        return None;
    }
    haystack[from..]
        .windows(n.len())
        .position(|w| w == n)
        .map(|i| from + i)
}

fn unescape(s: &str) -> Result<String> {
    if !s.contains('&') {
        return Ok(s.to_string());
    }
    let mut out = String::with_capacity(s.len());
    let mut rest = s;
    while let Some(i) = rest.find('&') {
        out.push_str(&rest[..i]);
        rest = &rest[i..];
        let semi = rest.find(';').ok_or_else(|| {
            FloeError::Parse("xml: unterminated entity".into())
        })?;
        let ent = &rest[1..semi];
        match ent {
            "amp" => out.push('&'),
            "lt" => out.push('<'),
            "gt" => out.push('>'),
            "quot" => out.push('"'),
            "apos" => out.push('\''),
            _ if ent.starts_with("#x") || ent.starts_with("#X") => {
                let code = u32::from_str_radix(&ent[2..], 16).map_err(
                    |_| FloeError::Parse(format!("xml: bad entity &{ent};")),
                )?;
                out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
            }
            _ if ent.starts_with('#') => {
                let code = ent[1..].parse::<u32>().map_err(|_| {
                    FloeError::Parse(format!("xml: bad entity &{ent};"))
                })?;
                out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
            }
            _ => {
                return Err(FloeError::Parse(format!(
                    "xml: unknown entity &{ent};"
                )))
            }
        }
        rest = &rest[semi + 1..];
    }
    out.push_str(rest);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_simple() {
        let n = XmlNode::parse("<a x=\"1\"><b>hi</b><b>yo</b></a>").unwrap();
        assert_eq!(n.name, "a");
        assert_eq!(n.attr("x"), Some("1"));
        assert_eq!(n.children_named("b").count(), 2);
        assert_eq!(n.children[0].text, "hi");
    }

    #[test]
    fn parse_self_closing_and_decl() {
        let n = XmlNode::parse(
            "<?xml version=\"1.0\"?>\n<!-- doc -->\n<g><p id='x'/></g>",
        )
        .unwrap();
        assert_eq!(n.child("p").unwrap().attr("id"), Some("x"));
    }

    #[test]
    fn entities_and_cdata() {
        let n = XmlNode::parse(
            "<t a=\"&lt;&amp;&gt;\">x &#65;<![CDATA[<raw>]]></t>",
        )
        .unwrap();
        assert_eq!(n.attr("a"), Some("<&>"));
        assert_eq!(n.text, "x A<raw>");
    }

    #[test]
    fn mismatched_tags_rejected() {
        assert!(XmlNode::parse("<a></b>").is_err());
        assert!(XmlNode::parse("<a>").is_err());
        assert!(XmlNode::parse("<a></a><b/>").is_err());
        assert!(XmlNode::parse("<a x=1></a>").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = "<graph name=\"g\"><pellet id=\"p1\" class=\"C\"/><edge from=\"p1\" to=\"p2\"/></graph>";
        let n = XmlNode::parse(src).unwrap();
        let n2 = XmlNode::parse(&n.to_xml()).unwrap();
        assert_eq!(n, n2);
    }

    #[test]
    fn noaa_style_document() {
        // Shape used by apps::smartgrid::NoaaXmlSource.
        let doc = "<current_observation><temp_f>71.2</temp_f>\
                   <wind_mph>4.5</wind_mph><station>KLAX</station>\
                   </current_observation>";
        let n = XmlNode::parse(doc).unwrap();
        assert_eq!(n.child("temp_f").unwrap().text, "71.2");
        assert_eq!(n.child("station").unwrap().text, "KLAX");
    }
}
