//! CRC-32 (IEEE 802.3 polynomial, reflected) — the wire-frame
//! checksum.  Table-driven, one byte per step; built from scratch
//! because the crate vendors no codec dependencies.  Fast enough for
//! the data plane (the per-frame cost is dwarfed by the syscall), and
//! a single flipped byte anywhere in the covered bytes always changes
//! the digest.

use std::sync::OnceLock;

const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { (c >> 1) ^ POLY } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC-32 of `bytes` (IEEE, as in zip/png/ethernet).
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = table();
    let mut c: u32 = !0;
    for b in bytes {
        c = (c >> 8) ^ t[((c ^ u32::from(*b)) & 0xFF) as usize];
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn single_byte_flip_always_detected() {
        let base: Vec<u8> = (0..=255u8).cycle().take(1024).collect();
        let digest = crc32(&base);
        let mut probe = base.clone();
        for i in (0..probe.len()).step_by(37) {
            probe[i] ^= 0x20;
            assert_ne!(crc32(&probe), digest, "flip at {i} undetected");
            probe[i] ^= 0x20;
        }
    }
}
