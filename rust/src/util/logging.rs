//! Minimal env-filtered logger for the `log` facade.
//!
//! Level comes from `FLOE_LOG` (`error|warn|info|debug|trace`, default
//! `info`).  Output goes to stderr with a monotonic timestamp, level and
//! module path — enough to trace coordinator/flake interactions.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct FloeLogger {
    start: Instant,
    max: Level,
}

impl Log for FloeLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.4}s {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<FloeLogger> = OnceLock::new();

/// Parse a level name, defaulting to `info`.
fn parse_level(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

/// Install the logger (idempotent).  Honors `FLOE_LOG`.
pub fn init() {
    let level = std::env::var("FLOE_LOG")
        .map(|v| parse_level(&v))
        .unwrap_or(Level::Info);
    let logger = LOGGER.get_or_init(|| FloeLogger {
        start: Instant::now(),
        max: level,
    });
    // Err only if a logger is already set — fine for tests calling init twice.
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::max());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Level::Error);
        assert_eq!(parse_level("WARN"), Level::Warn);
        assert_eq!(parse_level("debug"), Level::Debug);
        assert_eq!(parse_level("trace"), Level::Trace);
        assert_eq!(parse_level("bogus"), Level::Info);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke");
    }
}
