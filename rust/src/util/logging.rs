//! Minimal env-filtered logger, self-contained (the offline build has no
//! `log` facade crate).
//!
//! `FLOE_LOG` holds a comma-separated directive list: bare level names
//! set the default, `module=level` entries override per module prefix,
//! and `off` silences a scope entirely — e.g.
//! `FLOE_LOG=channel=debug,coordinator=trace,warn` or `FLOE_LOG=off`.
//! Module prefixes match path segments of `module_path!()` with the
//! leading `floe::` crate name optional, so `channel` covers
//! `floe::channel::ring` and friends.  Output goes to stderr with a
//! monotonic timestamp, level and module path.  Until [`init`] runs,
//! logging is disabled (mirroring an uninstalled facade).
//!
//! Call sites use the crate-root macros [`crate::log_error!`],
//! [`crate::log_warn!`], [`crate::log_info!`] and [`crate::log_debug!`];
//! each formats lazily, so a disabled level costs one atomic load.  A
//! `;`-separated trailer appends structured `key=value` pairs:
//!
//! ```ignore
//! log_info!("repair done"; container = id, replayed = n);
//! // => [  12.0034s INFO  floe::coordinator] repair done container=c1 replayed=42
//! ```

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

/// 0 = logging disabled (init not called); otherwise the max level
/// enabled by *any* directive — the one-atomic-load fast path.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

fn start_instant() -> &'static Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now)
}

/// Parse a level name, defaulting to `info`.
fn parse_level(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

/// Level rank with `off`/`none` as 0 (fully silenced).
fn parse_spec_level(s: &str) -> u8 {
    match s.to_ascii_lowercase().as_str() {
        "off" | "none" => 0,
        other => parse_level(other) as u8,
    }
}

/// Parsed `FLOE_LOG`: a default rank plus per-module-prefix overrides,
/// first match wins in directive order.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Directives {
    default: u8,
    mods: Vec<(String, u8)>,
}

impl Directives {
    fn parse(spec: &str) -> Directives {
        let mut default = Level::Info as u8;
        let mut mods = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            match tok.split_once('=') {
                Some((module, level)) => mods.push((
                    module.trim().to_string(),
                    parse_spec_level(level.trim()),
                )),
                None => default = parse_spec_level(tok),
            }
        }
        Directives { default, mods }
    }

    fn max_level(&self) -> u8 {
        self.mods.iter().map(|(_, l)| *l).fold(self.default, u8::max)
    }

    /// Enabled rank for a `module_path!()` target.
    fn level_for(&self, target: &str) -> u8 {
        let tail = target.strip_prefix("floe::").unwrap_or(target);
        for (prefix, level) in &self.mods {
            if module_matches(tail, prefix)
                || module_matches(target, prefix)
            {
                return *level;
            }
        }
        self.default
    }
}

/// `prefix` matches `target` on whole `::`-separated segments.
fn module_matches(target: &str, prefix: &str) -> bool {
    target.starts_with(prefix.as_str())
        && (target.len() == prefix.len()
            || target[prefix.len()..].starts_with("::"))
}

fn directives() -> Option<&'static Directives> {
    DIRECTIVES.get()
}

static DIRECTIVES: OnceLock<Directives> = OnceLock::new();

/// Install the logger (idempotent).  Honors `FLOE_LOG`; the first call
/// wins, later calls are no-ops.
pub fn init() {
    let dirs = DIRECTIVES.get_or_init(|| {
        Directives::parse(
            &std::env::var("FLOE_LOG").unwrap_or_default(),
        )
    });
    let _ = start_instant();
    MAX_LEVEL.store(dirs.max_level(), Ordering::SeqCst);
}

/// True when a record at `level` would be written by at least one
/// module (the cheap pre-filter; per-module filtering happens in
/// [`log`]).
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Write one record (used through the crate-root macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let Some(dirs) = directives() else { return };
    if level as u8 > dirs.level_for(target) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.4}s {level:5} {target}] {args}");
}

// The four level macros are spelled out (macro_rules cannot define
// macro_rules without unstable `$$` metavariables); each has a
// `"fmt"; key = value, …` arm for structured trailers plus the plain
// format passthrough.

#[macro_export]
macro_rules! log_error {
    ($fmt:literal; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!(
                concat!($fmt $(, " ", stringify!($k), "={}")+),
                $($v),+
            ),
        )
    };
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($fmt:literal; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!(
                concat!($fmt $(, " ", stringify!($k), "={}")+),
                $($v),+
            ),
        )
    };
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($fmt:literal; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!(
                concat!($fmt $(, " ", stringify!($k), "={}")+),
                $($v),+
            ),
        )
    };
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($fmt:literal; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!(
                concat!($fmt $(, " ", stringify!($k), "={}")+),
                $($v),+
            ),
        )
    };
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Level::Error);
        assert_eq!(parse_level("WARN"), Level::Warn);
        assert_eq!(parse_level("debug"), Level::Debug);
        assert_eq!(parse_level("trace"), Level::Trace);
        assert_eq!(parse_level("bogus"), Level::Info);
    }

    #[test]
    fn init_is_idempotent_and_enables_info() {
        init();
        init();
        assert!(enabled(Level::Error));
        crate::log_info!("logger smoke");
        crate::log_info!("logger smoke"; key = 1, other = "two");
    }

    #[test]
    fn level_ordering_is_severity_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
    }

    #[test]
    fn directives_parse_defaults_and_modules() {
        let d = Directives::parse("channel=debug,info");
        assert_eq!(d.default, Level::Info as u8);
        assert_eq!(d.mods, vec![("channel".into(), Level::Debug as u8)]);
        assert_eq!(d.max_level(), Level::Debug as u8);
        assert_eq!(Directives::parse("").default, Level::Info as u8);
        assert_eq!(Directives::parse("off").default, 0);
        let silent = Directives::parse("flake=off,warn");
        assert_eq!(silent.level_for("floe::flake::probes"), 0);
        assert_eq!(
            silent.level_for("floe::channel"),
            Level::Warn as u8
        );
    }

    #[test]
    fn module_prefix_matches_whole_segments() {
        let d = Directives::parse("channel=trace,coordinator=off,warn");
        assert_eq!(
            d.level_for("floe::channel::ring"),
            Level::Trace as u8
        );
        assert_eq!(d.level_for("floe::channel"), Level::Trace as u8);
        // `channel` must not match `channels` or mid-segment text.
        assert_eq!(d.level_for("floe::channels"), Level::Warn as u8);
        assert_eq!(d.level_for("floe::coordinator::server"), 0);
        assert_eq!(d.level_for("floe::recompose"), Level::Warn as u8);
    }
}
