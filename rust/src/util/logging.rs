//! Minimal env-filtered logger, self-contained (the offline build has no
//! `log` facade crate).
//!
//! Level comes from `FLOE_LOG` (`error|warn|info|debug|trace`, default
//! `info`).  Output goes to stderr with a monotonic timestamp, level and
//! module path — enough to trace coordinator/flake interactions.  Until
//! [`init`] runs, logging is disabled (mirroring an uninstalled facade).
//!
//! Call sites use the crate-root macros [`crate::log_error!`],
//! [`crate::log_warn!`], [`crate::log_info!`] and [`crate::log_debug!`];
//! each formats lazily, so a disabled level costs one atomic load.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

/// 0 = logging disabled (init not called); otherwise the max enabled
/// level as its numeric rank.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

fn start_instant() -> &'static Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now)
}

/// Parse a level name, defaulting to `info`.
fn parse_level(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

/// Install the logger (idempotent).  Honors `FLOE_LOG`.
pub fn init() {
    let level = std::env::var("FLOE_LOG")
        .map(|v| parse_level(&v))
        .unwrap_or(Level::Info);
    let _ = start_instant();
    MAX_LEVEL.store(level as u8, Ordering::SeqCst);
}

/// True when a record at `level` would be written.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Write one record (used through the crate-root macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.4}s {level:5} {target}] {args}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Level::Error);
        assert_eq!(parse_level("WARN"), Level::Warn);
        assert_eq!(parse_level("debug"), Level::Debug);
        assert_eq!(parse_level("trace"), Level::Trace);
        assert_eq!(parse_level("bogus"), Level::Info);
    }

    #[test]
    fn init_is_idempotent_and_enables_info() {
        init();
        init();
        assert!(enabled(Level::Error));
        crate::log_info!("logger smoke");
    }

    #[test]
    fn level_ordering_is_severity_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
    }
}
