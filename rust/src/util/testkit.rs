//! Mini property-testing harness (no proptest offline): deterministic
//! generators over a seeded [`Rng`](crate::util::rng::Rng), many cases per
//! property, and a failure report that names the seed so any counterexample
//! is replayable.
//!
//! ```no_run
//! use floe::util::testkit::{run_cases, Gen};
//! run_cases("sorted stays sorted", 100, |g| {
//!     let mut v = g.vec_of(0..50, |g| g.int(0, 1000));
//!     v.sort();
//!     assert!(v.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use crate::util::rng::Rng;

/// Value generator handle passed to each property case.
pub struct Gen {
    rng: Rng,
    /// Seed for this case, for the failure report.
    pub seed: u64,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.rng.range(0, n.max(1))
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector with a length drawn from `len` and elements from `f`.
    pub fn vec_of<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.rng.range(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| f(self)).collect()
    }

    /// ASCII alphanumeric string of length in `len`.
    pub fn string(&mut self, len: std::ops::Range<usize>) -> String {
        const CHARS: &[u8] =
            b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789 _-";
        let n = self.rng.range(len.start, len.end.max(len.start + 1));
        (0..n)
            .map(|_| CHARS[self.rng.range(0, CHARS.len())] as char)
            .collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.pick(items)
    }

    /// Access the underlying RNG for distributions testkit doesn't wrap.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` instances of a property.  Panics (re-raising the case's
/// panic) with the offending seed in the message on first failure.
pub fn run_cases(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    run_cases_seeded(name, 0xF10E_BA5E, cases, &mut prop);
}

/// As [`run_cases`] with an explicit base seed (use the seed printed by a
/// failure to replay just that case).
pub fn run_cases_seeded(
    name: &str,
    base_seed: u64,
    cases: u64,
    prop: &mut impl FnMut(&mut Gen),
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), seed };
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut g)),
        );
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_cases("reverse twice is identity", 50, |g| {
            let v = g.vec_of(0..20, |g| g.int(-100, 100));
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            assert_eq!(v, r);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            run_cases("always fails", 3, |_g| {
                panic!("boom");
            });
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn generators_in_bounds() {
        run_cases("bounds", 200, |g| {
            let i = g.int(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f64(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let s = g.string(1..10);
            assert!(!s.is_empty() && s.len() < 10);
            let v = g.vec_of(2..4, |g| g.bool(0.5));
            assert!(v.len() >= 2 && v.len() < 4);
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<i64> = Vec::new();
        run_cases_seeded("collect", 10, 5, &mut |g| {
            first.push(g.int(0, 1_000_000));
        });
        let mut second: Vec<i64> = Vec::new();
        run_cases_seeded("collect", 10, 5, &mut |g| {
            second.push(g.int(0, 1_000_000));
        });
        assert_eq!(first, second);
    }
}
