//! Clocks: a monotonic wall clock for live execution and a shared virtual
//! clock for the discrete-event simulator, behind one trait so the
//! adaptation strategies run unchanged in both worlds.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Time source measured in seconds since an arbitrary epoch.
pub trait Clock: Send + Sync {
    /// Current time in seconds.
    fn now(&self) -> f64;
}

/// Monotonic wall clock.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Virtual clock advanced explicitly by the simulator.  Stores microseconds
/// in an atomic so readers never block the event loop.
#[derive(Clone)]
pub struct VirtualClock {
    micros: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { micros: Arc::new(AtomicU64::new(0)) }
    }

    /// Advance to an absolute time (seconds). Time never moves backwards.
    pub fn advance_to(&self, t: f64) {
        let target = (t.max(0.0) * 1e6) as u64;
        self.micros.fetch_max(target, Ordering::SeqCst);
    }

    /// Advance by a delta (seconds).
    pub fn advance_by(&self, dt: f64) {
        let delta = (dt.max(0.0) * 1e6) as u64;
        self.micros.fetch_add(delta, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.micros.load(Ordering::SeqCst) as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotonic() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_by(0.25);
        assert!((c.now() - 1.75).abs() < 1e-9);
    }

    #[test]
    fn virtual_clock_never_goes_back() {
        let c = VirtualClock::new();
        c.advance_to(10.0);
        c.advance_to(5.0);
        assert!((c.now() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn virtual_clock_shared_between_clones() {
        let c = VirtualClock::new();
        let c2 = c.clone();
        c.advance_to(3.0);
        assert!((c2.now() - 3.0).abs() < 1e-9);
    }
}
