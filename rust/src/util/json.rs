//! Minimal JSON: value model, recursive-descent parser, serializer.
//!
//! Used for the artifact manifest, the REST control plane and metrics dumps.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP are passed through unpaired (not needed by our producers).

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{FloeError, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    /// Builder: string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Builder: numeric value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> FloeError {
        FloeError::Parse(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'),
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8 that we split as bytes.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": {"d": "e"}, "f": true}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_str().unwrap(),
            "e"
        );
        assert_eq!(v.get("f").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aπ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aπ");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":null},"t":false}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn display_escapes() {
        let s = Json::str("a\"b\\c\nd").to_string();
        assert_eq!(Json::parse(&s).unwrap(), Json::str("a\"b\\c\nd"));
    }

    #[test]
    fn manifest_shape() {
        // Mirrors artifacts/manifest.json produced by python/compile/aot.py.
        let m = Json::parse(
            r#"{"config": {"batch": 32, "dim": 64},
                "entries": {"bucketize": {"file": "bucketize.hlo.txt",
                "inputs": [{"shape": [32, 64], "dtype": "float32"}]}}}"#,
        )
        .unwrap();
        let e = m.get("entries").unwrap().get("bucketize").unwrap();
        assert_eq!(e.get("file").unwrap().as_str().unwrap(), "bucketize.hlo.txt");
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![32, 64]);
    }
}
