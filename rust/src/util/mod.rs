//! Substrate utilities built from scratch (the offline vendored crate set has
//! no serde/tokio/hyper/rand): PRNG, logging, JSON, XML, HTTP/1.1, CSV,
//! clocks and a mini property-testing harness.

pub mod crc;
pub mod csv;
pub mod http;
pub mod json;
pub mod logging;
pub mod netpoll;
pub mod rng;
pub mod testkit;
pub mod time;
pub mod xml;
