//! Containers: VM-granularity resource runtime (§III).
//!
//! A container manages the flakes placed on one (simulated) VM, accounts
//! the VM's cores across them, and exposes the fine-grained control used
//! by the coordinator and the adaptation strategies: spawn flake, change a
//! flake's core allocation, pause/resume/update.  An optional REST control
//! endpoint mirrors the paper's management interface.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::error::{FloeError, Result};
use crate::flake::{Flake, FlakeConfig};
use crate::pellet::PelletFactory;
use crate::util::http::{HttpServer, Request, Response};
use crate::util::json::Json;

/// A container bound to one VM's cores.
pub struct Container {
    pub id: String,
    total_cores: usize,
    inner: Mutex<Inner>,
}

struct Inner {
    flakes: HashMap<String, Arc<Flake>>,
    /// Cores currently granted per flake.
    grants: HashMap<String, usize>,
}

impl Container {
    pub fn new(id: impl Into<String>, total_cores: usize) -> Arc<Container> {
        Arc::new(Container {
            id: id.into(),
            total_cores,
            inner: Mutex::new(Inner {
                flakes: HashMap::new(),
                grants: HashMap::new(),
            }),
        })
    }

    pub fn total_cores(&self) -> usize {
        self.total_cores
    }

    /// Cores not granted to any flake.
    pub fn free_cores(&self) -> usize {
        let inner = self.inner.lock().expect("container poisoned");
        self.total_cores
            .saturating_sub(inner.grants.values().sum::<usize>())
    }

    pub fn flake_count(&self) -> usize {
        self.inner.lock().expect("container poisoned").flakes.len()
    }

    /// Spawn a flake with `cfg.cores` cores from this container's budget.
    pub fn spawn_flake(
        &self,
        cfg: FlakeConfig,
        factory: PelletFactory,
    ) -> Result<Arc<Flake>> {
        let want = cfg.cores.max(1);
        let mut inner = self.inner.lock().expect("container poisoned");
        let used: usize = inner.grants.values().sum();
        if used + want > self.total_cores {
            return Err(FloeError::Resource(format!(
                "container {}: need {want} cores, {} free",
                self.id,
                self.total_cores - used
            )));
        }
        if inner.flakes.contains_key(&cfg.pellet_id) {
            return Err(FloeError::Resource(format!(
                "container {}: flake '{}' already exists",
                self.id, cfg.pellet_id
            )));
        }
        let id = cfg.pellet_id.clone();
        let flake = Flake::start(cfg, factory);
        inner.grants.insert(id.clone(), want);
        inner.flakes.insert(id, Arc::clone(&flake));
        Ok(flake)
    }

    /// Look up a hosted flake.
    pub fn flake(&self, pellet_id: &str) -> Option<Arc<Flake>> {
        self.inner
            .lock()
            .expect("container poisoned")
            .flakes
            .get(pellet_id)
            .cloned()
    }

    pub fn flake_ids(&self) -> Vec<String> {
        self.inner
            .lock()
            .expect("container poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }

    /// Re-grant cores to a flake (dynamic adaptation).  Fails if the
    /// container cannot cover the increase — cross-VM elasticity is the
    /// manager's job.
    pub fn set_flake_cores(
        &self,
        pellet_id: &str,
        cores: usize,
    ) -> Result<()> {
        let cores = cores.max(1);
        let mut inner = self.inner.lock().expect("container poisoned");
        let current =
            *inner.grants.get(pellet_id).ok_or_else(|| {
                FloeError::Resource(format!(
                    "container {}: no flake '{pellet_id}'",
                    self.id
                ))
            })?;
        let others: usize = inner
            .grants
            .iter()
            .filter(|(k, _)| k.as_str() != pellet_id)
            .map(|(_, v)| *v)
            .sum();
        if others + cores > self.total_cores {
            return Err(FloeError::Resource(format!(
                "container {}: cannot grow '{pellet_id}' to {cores} cores \
                 ({} total, {others} used by others)",
                self.id, self.total_cores
            )));
        }
        if cores != current {
            inner.grants.insert(pellet_id.to_string(), cores);
            inner.flakes[pellet_id].set_cores(cores);
        }
        Ok(())
    }

    /// Remove and stop a flake, freeing its cores (sub-graph removal).
    pub fn remove_flake(&self, pellet_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("container poisoned");
        let flake = inner.flakes.remove(pellet_id).ok_or_else(|| {
            FloeError::Resource(format!(
                "container {}: no flake '{pellet_id}'",
                self.id
            ))
        })?;
        inner.grants.remove(pellet_id);
        drop(inner);
        flake.shutdown();
        Ok(())
    }

    /// Stop everything.
    pub fn shutdown(&self) {
        let mut inner = self.inner.lock().expect("container poisoned");
        for (_, f) in inner.flakes.drain() {
            f.shutdown();
        }
        inner.grants.clear();
    }

    /// JSON status document (also served by the REST endpoint).
    pub fn status_json(&self) -> Json {
        let inner = self.inner.lock().expect("container poisoned");
        let mut flakes = Vec::new();
        for (id, f) in &inner.flakes {
            flakes.push(Json::obj(vec![
                ("id", Json::str(id.clone())),
                ("class", Json::str(f.class())),
                ("cores", Json::num(inner.grants[id] as f64)),
                ("instances", Json::num(f.instances() as f64)),
                ("queue", Json::num(f.queue_len() as f64)),
                ("version", Json::num(f.version() as f64)),
            ]));
        }
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("total_cores", Json::num(self.total_cores as f64)),
            (
                "used_cores",
                Json::num(inner.grants.values().sum::<usize>() as f64),
            ),
            ("flakes", Json::Arr(flakes)),
        ])
    }

    /// Start the REST control endpoint:
    /// `GET /status`, `POST /flake/{id}/cores?n=`, `POST /flake/{id}/pause`,
    /// `POST /flake/{id}/resume`.
    pub fn serve(self: &Arc<Self>, port: u16) -> Result<HttpServer> {
        let me = Arc::clone(self);
        HttpServer::start(port, move |req| me.handle(req))
    }

    fn handle(&self, req: &Request) -> Response {
        let parts: Vec<&str> =
            req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), parts.as_slice()) {
            ("GET", ["status"]) => {
                Response::ok_json(self.status_json().to_string())
            }
            ("POST", ["flake", id, "cores"]) => {
                let n = req
                    .query_get("n")
                    .and_then(|v| v.parse::<usize>().ok());
                match n {
                    None => Response::error(400, "missing ?n="),
                    Some(n) => match self.set_flake_cores(id, n) {
                        Ok(()) => Response::ok_json("{\"ok\":true}"),
                        Err(e) => Response::error(409, e.to_string()),
                    },
                }
            }
            ("POST", ["flake", id, "pause"]) => match self.flake(id) {
                Some(f) => {
                    f.pause();
                    Response::ok_json("{\"ok\":true}")
                }
                None => Response::error(404, "no such flake"),
            },
            ("POST", ["flake", id, "resume"]) => match self.flake(id) {
                Some(f) => {
                    f.resume();
                    Response::ok_json("{\"ok\":true}")
                }
                None => Response::error(404, "no such flake"),
            },
            _ => Response::error(404, "unknown control path"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{
        InPortSpec, MergeMode, OutPortSpec, SplitMode, TriggerMode,
        WindowSpec,
    };
    use std::sync::Arc;

    fn cfg(id: &str, cores: usize) -> FlakeConfig {
        FlakeConfig {
            pellet_id: id.into(),
            class: "floe.builtin.Identity".into(),
            inputs: vec![InPortSpec {
                name: "in".into(),
                window: WindowSpec::None,
            }],
            outputs: vec![OutPortSpec {
                name: "out".into(),
                split: SplitMode::RoundRobin,
            }],
            merge: MergeMode::Interleaved,
            trigger: TriggerMode::Push,
            sequential: false,
            stateful: false,
            cores,
            alpha: 2,
            queue_capacity: 64,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: 2,
            channel_backend: crate::channel::ChannelBackend::default(),
        }
    }

    fn factory() -> PelletFactory {
        Arc::new(|| Box::new(crate::pellet::builtins::Identity))
    }

    #[test]
    fn core_accounting() {
        let c = Container::new("vm0", 8);
        assert_eq!(c.free_cores(), 8);
        c.spawn_flake(cfg("a", 3), factory()).unwrap();
        c.spawn_flake(cfg("b", 4), factory()).unwrap();
        assert_eq!(c.free_cores(), 1);
        // over-subscription rejected
        assert!(c.spawn_flake(cfg("c", 2), factory()).is_err());
        // duplicate id rejected
        assert!(c.spawn_flake(cfg("a", 1), factory()).is_err());
        c.shutdown();
    }

    #[test]
    fn regrant_cores_within_budget() {
        let c = Container::new("vm0", 8);
        c.spawn_flake(cfg("a", 2), factory()).unwrap();
        c.set_flake_cores("a", 6).unwrap();
        assert_eq!(c.free_cores(), 2);
        assert_eq!(c.flake("a").unwrap().cores(), 6);
        assert!(c.set_flake_cores("a", 9).is_err());
        assert!(c.set_flake_cores("ghost", 1).is_err());
        c.shutdown();
    }

    #[test]
    fn remove_frees_cores() {
        let c = Container::new("vm0", 4);
        c.spawn_flake(cfg("a", 4), factory()).unwrap();
        assert_eq!(c.free_cores(), 0);
        c.remove_flake("a").unwrap();
        assert_eq!(c.free_cores(), 4);
        assert_eq!(c.flake_count(), 0);
        c.shutdown();
    }

    #[test]
    fn rest_control_plane() {
        let c = Container::new("vm0", 8);
        c.spawn_flake(cfg("a", 2), factory()).unwrap();
        let mut srv = c.serve(0).unwrap();
        let addr = srv.addr();
        let status =
            crate::util::http::http_get(&addr, "/status").unwrap();
        let j = Json::parse(&status).unwrap();
        assert_eq!(j.get("total_cores").unwrap().as_usize(), Some(8));
        crate::util::http::http_post(&addr, "/flake/a/cores?n=5", "")
            .unwrap();
        assert_eq!(c.flake("a").unwrap().cores(), 5);
        assert!(crate::util::http::http_post(
            &addr,
            "/flake/a/cores?n=99",
            ""
        )
        .is_err());
        crate::util::http::http_post(&addr, "/flake/a/pause", "").unwrap();
        assert!(c.flake("a").unwrap().is_paused());
        crate::util::http::http_post(&addr, "/flake/a/resume", "").unwrap();
        assert!(!c.flake("a").unwrap().is_paused());
        srv.shutdown();
        c.shutdown();
    }
}
