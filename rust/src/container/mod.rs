//! Containers: VM-granularity resource runtime (§III).
//!
//! A container manages the flakes placed on one (simulated) VM, accounts
//! the VM's cores across them, and exposes the fine-grained control used
//! by the coordinator and the adaptation strategies: spawn flake, change a
//! flake's core allocation, pause/resume/update.  An optional REST control
//! endpoint mirrors the paper's management interface.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::error::{FloeError, Result};
use crate::flake::{Flake, FlakeConfig};
use crate::pellet::PelletFactory;
use crate::util::http::{HttpServer, Request, Response};
use crate::util::json::Json;

/// The monotonic heartbeat a container publishes while alive.  The
/// coordinator's failure detector samples [`Container::heartbeat`]
/// each lease tick; a counter that stops advancing is a dead
/// container (see `crate::coordinator::LeaseTracker`).
struct Heart {
    beat: AtomicU64,
    stop: AtomicBool,
}

/// A container bound to one VM's cores.
pub struct Container {
    pub id: String,
    total_cores: usize,
    inner: Mutex<Inner>,
    heart: Arc<Heart>,
    hb_join: Mutex<Option<thread::JoinHandle<()>>>,
    dead: AtomicBool,
    /// Chaos partition latch: `u64::MAX` = delivering live beats;
    /// anything else is the frozen value [`Container::heartbeat`]
    /// keeps reporting while a partition window covers this
    /// container.  The heartbeat *thread* keeps running — only the
    /// coordinator's view stalls, exactly like heartbeats delayed in
    /// a partitioned network.
    hb_frozen: AtomicU64,
}

struct Inner {
    flakes: HashMap<String, Arc<Flake>>,
    /// Cores currently granted per flake.
    grants: HashMap<String, usize>,
}

impl Container {
    pub fn new(id: impl Into<String>, total_cores: usize) -> Arc<Container> {
        Arc::new(Container {
            id: id.into(),
            total_cores,
            inner: Mutex::new(Inner {
                flakes: HashMap::new(),
                grants: HashMap::new(),
            }),
            heart: Arc::new(Heart {
                beat: AtomicU64::new(0),
                stop: AtomicBool::new(false),
            }),
            hb_join: Mutex::new(None),
            dead: AtomicBool::new(false),
            hb_frozen: AtomicU64::new(u64::MAX),
        })
    }

    /// Start the heartbeat thread bumping [`Container::heartbeat`]
    /// every `interval`.  Idempotent: a no-op while a heartbeat is
    /// already running, or on a dead container (so the failure
    /// detector can call it every tick to adopt containers provisioned
    /// after launch).
    pub fn start_heartbeat(&self, interval: Duration) {
        if self.is_dead() {
            return;
        }
        let mut join = self.hb_join.lock().expect("heartbeat poisoned");
        if join.is_some() {
            return;
        }
        self.heart.stop.store(false, Ordering::SeqCst);
        let heart = Arc::clone(&self.heart);
        let handle = thread::Builder::new()
            .name(format!("floe-hb-{}", self.id))
            .spawn(move || {
                while !heart.stop.load(Ordering::SeqCst) {
                    heart.beat.fetch_add(1, Ordering::SeqCst);
                    thread::sleep(interval);
                }
            })
            .expect("spawn heartbeat");
        *join = Some(handle);
    }

    /// Current heartbeat counter (frozen forever once the container
    /// dies).  While an armed chaos plan partitions this container
    /// from the coordinator, the value observed here freezes — the
    /// beats are "in flight but undelivered" — and resumes live once
    /// the window closes.
    pub fn heartbeat(&self) -> u64 {
        let live = self.heart.beat.load(Ordering::SeqCst);
        if crate::chaos::heartbeat_stalled(&self.id) {
            let frozen = self.hb_frozen.load(Ordering::SeqCst);
            if frozen == u64::MAX {
                // Window onset: latch the last delivered value.
                self.hb_frozen.store(live, Ordering::SeqCst);
                return live;
            }
            return frozen;
        }
        if self.hb_frozen.load(Ordering::SeqCst) != u64::MAX {
            self.hb_frozen.store(u64::MAX, Ordering::SeqCst);
        }
        live
    }

    /// Stop the heartbeat thread (graceful shutdown path; does not
    /// mark the container dead).
    pub fn stop_heartbeat(&self) {
        self.heart.stop.store(true, Ordering::SeqCst);
        if let Some(j) =
            self.hb_join.lock().expect("heartbeat poisoned").take()
        {
            let _ = j.join();
        }
    }

    /// Whether this container has been declared (or made) dead.  Dead
    /// containers reject new flakes and are skipped by placement.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Declare the container dead without touching its flakes — the
    /// failure detector calls this when the lease expires (a really
    /// crashed container's flakes are already gone; marking just
    /// fences placement).
    pub fn mark_dead(&self) {
        self.dead.store(true, Ordering::SeqCst);
        self.stop_heartbeat();
    }

    /// Simulate a container crash: freeze the heartbeat and
    /// crash-stop every hosted flake *without* unpublishing its
    /// endpoints — a crashed host cannot run cleanup, so stale
    /// logical routes linger until a repair republishes them (exactly
    /// what upstream retry has to bridge).  The flake/grant maps stay
    /// populated: repair still reads the husk's config and the
    /// containing entry, like a coordinator inspecting its records of
    /// a lost remote host.
    pub fn kill(&self) {
        self.mark_dead();
        let flakes: Vec<Arc<Flake>> = {
            let inner = self.inner.lock().expect("container poisoned");
            inner.flakes.values().cloned().collect()
        };
        for f in flakes {
            f.crash();
        }
    }

    pub fn total_cores(&self) -> usize {
        self.total_cores
    }

    /// Cores not granted to any flake.
    pub fn free_cores(&self) -> usize {
        let inner = self.inner.lock().expect("container poisoned");
        self.total_cores
            .saturating_sub(inner.grants.values().sum::<usize>())
    }

    pub fn flake_count(&self) -> usize {
        self.inner.lock().expect("container poisoned").flakes.len()
    }

    /// Spawn a flake with `cfg.cores` cores from this container's budget.
    pub fn spawn_flake(
        &self,
        cfg: FlakeConfig,
        factory: PelletFactory,
    ) -> Result<Arc<Flake>> {
        if self.is_dead() {
            return Err(FloeError::Resource(format!(
                "container {}: dead, cannot spawn '{}'",
                self.id, cfg.pellet_id
            )));
        }
        let want = cfg.cores.max(1);
        let mut inner = self.inner.lock().expect("container poisoned");
        let used: usize = inner.grants.values().sum();
        if used + want > self.total_cores {
            return Err(FloeError::Resource(format!(
                "container {}: need {want} cores, {} free",
                self.id,
                self.total_cores - used
            )));
        }
        if inner.flakes.contains_key(&cfg.pellet_id) {
            return Err(FloeError::Resource(format!(
                "container {}: flake '{}' already exists",
                self.id, cfg.pellet_id
            )));
        }
        let id = cfg.pellet_id.clone();
        let flake = Flake::start(cfg, factory);
        inner.grants.insert(id.clone(), want);
        inner.flakes.insert(id, Arc::clone(&flake));
        Ok(flake)
    }

    /// Look up a hosted flake.
    pub fn flake(&self, pellet_id: &str) -> Option<Arc<Flake>> {
        self.inner
            .lock()
            .expect("container poisoned")
            .flakes
            .get(pellet_id)
            .cloned()
    }

    pub fn flake_ids(&self) -> Vec<String> {
        self.inner
            .lock()
            .expect("container poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }

    /// Re-grant cores to a flake (dynamic adaptation).  Fails if the
    /// container cannot cover the increase — cross-VM elasticity is the
    /// manager's job.
    pub fn set_flake_cores(
        &self,
        pellet_id: &str,
        cores: usize,
    ) -> Result<()> {
        let cores = cores.max(1);
        let mut inner = self.inner.lock().expect("container poisoned");
        let current =
            *inner.grants.get(pellet_id).ok_or_else(|| {
                FloeError::Resource(format!(
                    "container {}: no flake '{pellet_id}'",
                    self.id
                ))
            })?;
        let others: usize = inner
            .grants
            .iter()
            .filter(|(k, _)| k.as_str() != pellet_id)
            .map(|(_, v)| *v)
            .sum();
        if others + cores > self.total_cores {
            return Err(FloeError::Resource(format!(
                "container {}: cannot grow '{pellet_id}' to {cores} cores \
                 ({} total, {others} used by others)",
                self.id, self.total_cores
            )));
        }
        if cores != current {
            inner.grants.insert(pellet_id.to_string(), cores);
            inner.flakes[pellet_id].set_cores(cores);
        }
        Ok(())
    }

    /// Remove and stop a flake, freeing its cores (sub-graph removal).
    pub fn remove_flake(&self, pellet_id: &str) -> Result<()> {
        let mut inner = self.inner.lock().expect("container poisoned");
        let flake = inner.flakes.remove(pellet_id).ok_or_else(|| {
            FloeError::Resource(format!(
                "container {}: no flake '{pellet_id}'",
                self.id
            ))
        })?;
        inner.grants.remove(pellet_id);
        drop(inner);
        flake.shutdown();
        Ok(())
    }

    /// Stop everything.
    pub fn shutdown(&self) {
        self.stop_heartbeat();
        let mut inner = self.inner.lock().expect("container poisoned");
        for (_, f) in inner.flakes.drain() {
            f.shutdown();
        }
        inner.grants.clear();
    }

    /// JSON status document (also served by the REST endpoint).
    pub fn status_json(&self) -> Json {
        let inner = self.inner.lock().expect("container poisoned");
        let mut flakes = Vec::new();
        for (id, f) in &inner.flakes {
            flakes.push(Json::obj(vec![
                ("id", Json::str(id.clone())),
                ("class", Json::str(f.class())),
                ("cores", Json::num(inner.grants[id] as f64)),
                ("instances", Json::num(f.instances() as f64)),
                ("queue", Json::num(f.queue_len() as f64)),
                ("version", Json::num(f.version() as f64)),
            ]));
        }
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("total_cores", Json::num(self.total_cores as f64)),
            (
                "used_cores",
                Json::num(inner.grants.values().sum::<usize>() as f64),
            ),
            ("flakes", Json::Arr(flakes)),
        ])
    }

    /// Start the REST control endpoint:
    /// `GET /status`, `POST /flake/{id}/cores?n=`, `POST /flake/{id}/pause`,
    /// `POST /flake/{id}/resume`.
    pub fn serve(self: &Arc<Self>, port: u16) -> Result<HttpServer> {
        let me = Arc::clone(self);
        HttpServer::start(port, move |req| me.handle(req))
    }

    fn handle(&self, req: &Request) -> Response {
        let parts: Vec<&str> =
            req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), parts.as_slice()) {
            ("GET", ["status"]) => {
                Response::ok_json(self.status_json().to_string())
            }
            ("POST", ["flake", id, "cores"]) => {
                let n = req
                    .query_get("n")
                    .and_then(|v| v.parse::<usize>().ok());
                match n {
                    None => Response::error(400, "missing ?n="),
                    Some(n) => match self.set_flake_cores(id, n) {
                        Ok(()) => Response::ok_json("{\"ok\":true}"),
                        Err(e) => Response::error(409, e.to_string()),
                    },
                }
            }
            ("POST", ["flake", id, "pause"]) => match self.flake(id) {
                Some(f) => {
                    f.pause();
                    Response::ok_json("{\"ok\":true}")
                }
                None => Response::error(404, "no such flake"),
            },
            ("POST", ["flake", id, "resume"]) => match self.flake(id) {
                Some(f) => {
                    f.resume();
                    Response::ok_json("{\"ok\":true}")
                }
                None => Response::error(404, "no such flake"),
            },
            _ => Response::error(404, "unknown control path"),
        }
    }
}

impl Drop for Container {
    fn drop(&mut self) {
        // Never leak a heartbeat thread past the container's life.
        self.stop_heartbeat();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{
        InPortSpec, MergeMode, OutPortSpec, SplitMode, TriggerMode,
        WindowSpec,
    };
    use std::sync::Arc;

    fn cfg(id: &str, cores: usize) -> FlakeConfig {
        FlakeConfig {
            pellet_id: id.into(),
            class: "floe.builtin.Identity".into(),
            inputs: vec![InPortSpec {
                name: "in".into(),
                window: WindowSpec::None,
            }],
            outputs: vec![OutPortSpec {
                name: "out".into(),
                split: SplitMode::RoundRobin,
            }],
            merge: MergeMode::Interleaved,
            trigger: TriggerMode::Push,
            sequential: false,
            stateful: false,
            cores,
            alpha: 2,
            queue_capacity: 64,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: 2,
            channel_backend: crate::channel::ChannelBackend::default(),
            dedup: false,
        }
    }

    fn factory() -> PelletFactory {
        Arc::new(|| Box::new(crate::pellet::builtins::Identity))
    }

    #[test]
    fn core_accounting() {
        let c = Container::new("vm0", 8);
        assert_eq!(c.free_cores(), 8);
        c.spawn_flake(cfg("a", 3), factory()).unwrap();
        c.spawn_flake(cfg("b", 4), factory()).unwrap();
        assert_eq!(c.free_cores(), 1);
        // over-subscription rejected
        assert!(c.spawn_flake(cfg("c", 2), factory()).is_err());
        // duplicate id rejected
        assert!(c.spawn_flake(cfg("a", 1), factory()).is_err());
        c.shutdown();
    }

    #[test]
    fn regrant_cores_within_budget() {
        let c = Container::new("vm0", 8);
        c.spawn_flake(cfg("a", 2), factory()).unwrap();
        c.set_flake_cores("a", 6).unwrap();
        assert_eq!(c.free_cores(), 2);
        assert_eq!(c.flake("a").unwrap().cores(), 6);
        assert!(c.set_flake_cores("a", 9).is_err());
        assert!(c.set_flake_cores("ghost", 1).is_err());
        c.shutdown();
    }

    #[test]
    fn remove_frees_cores() {
        let c = Container::new("vm0", 4);
        c.spawn_flake(cfg("a", 4), factory()).unwrap();
        assert_eq!(c.free_cores(), 0);
        c.remove_flake("a").unwrap();
        assert_eq!(c.free_cores(), 4);
        assert_eq!(c.flake_count(), 0);
        c.shutdown();
    }

    #[test]
    fn heartbeat_advances_then_freezes_on_kill() {
        let c = Container::new("vm0", 8);
        c.spawn_flake(cfg("a", 2), factory()).unwrap();
        assert_eq!(c.heartbeat(), 0);
        c.start_heartbeat(Duration::from_millis(2));
        // Idempotent second start.
        c.start_heartbeat(Duration::from_millis(2));
        let deadline = std::time::Instant::now()
            + Duration::from_secs(2);
        while c.heartbeat() < 3 {
            assert!(std::time::Instant::now() < deadline, "no beats");
            thread::sleep(Duration::from_millis(2));
        }
        c.kill();
        assert!(c.is_dead());
        let frozen = c.heartbeat();
        thread::sleep(Duration::from_millis(20));
        assert_eq!(c.heartbeat(), frozen, "beat after kill");
        // Dead containers reject new flakes and new heartbeats.
        assert!(c.spawn_flake(cfg("b", 1), factory()).is_err());
        c.start_heartbeat(Duration::from_millis(2));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(c.heartbeat(), frozen);
        // The husk's records survive the crash for repair to read.
        assert_eq!(c.flake_count(), 1);
        c.shutdown();
    }

    #[test]
    fn rest_control_plane() {
        let c = Container::new("vm0", 8);
        c.spawn_flake(cfg("a", 2), factory()).unwrap();
        let mut srv = c.serve(0).unwrap();
        let addr = srv.addr();
        let status =
            crate::util::http::http_get(&addr, "/status").unwrap();
        let j = Json::parse(&status).unwrap();
        assert_eq!(j.get("total_cores").unwrap().as_usize(), Some(8));
        crate::util::http::http_post(&addr, "/flake/a/cores?n=5", "")
            .unwrap();
        assert_eq!(c.flake("a").unwrap().cores(), 5);
        assert!(crate::util::http::http_post(
            &addr,
            "/flake/a/cores?n=99",
            ""
        )
        .is_err());
        crate::util::http::http_post(&addr, "/flake/a/pause", "").unwrap();
        assert!(c.flake("a").unwrap().is_paused());
        crate::util::http::http_post(&addr, "/flake/a/resume", "").unwrap();
        assert!(!c.flake("a").unwrap().is_paused());
        srv.shutdown();
        c.shutdown();
    }
}
