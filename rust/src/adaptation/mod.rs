//! Resource-adaptation strategies (§III "Resource Adaptation Strategies").
//!
//! Three strategies decide per-flake core allocations from flake
//! instrumentation:
//!
//! * [`StaticLookAhead`] — the "oracle user" allocation: fixed cores per
//!   pellet computed from hinted latency/selectivity/rate:
//!   `P_i ≈ (l_i × m_i)/(t + ε)`, `m_i = m_{i-1} × s_i`, `C_i = ⌈P_i/α⌉`.
//! * [`DynamicStrategy`] — Algorithm 1: compares the instantaneous arrival
//!   rate with the processing capacity and scales cores up/down, with a
//!   hysteresis check so the allocation does not flutter.
//! * [`HybridStrategy`] — takes the static hints but does not trust the
//!   oracle: switches to dynamic when the observed rate deviates beyond a
//!   threshold, and back when it stabilizes near the hint with an empty
//!   queue.
//!
//! Strategies are pure decision functions over [`FlakeObservation`]s, so
//! the same code drives live flakes (via [`Monitor`]) and the Fig. 4
//! simulator ([`crate::sim`]).
//!
//! The control stack layers as **strategy → policy → recompose**: a
//! strategy decides how many cores one flake wants; the
//! [`elastic::ElasticityPolicy`] applies that decision within the
//! hosting container and, when the container stays saturated, escalates
//! to a [`crate::recompose`] `RelocateFlake` delta that migrates the hot
//! flake to a container chosen by
//! [`crate::manager::ResourceManager::allocate_avoiding`].  The
//! [`Monitor`] resolves flakes *by id* through a [`FlakeDirectory`] on
//! every tick, so graph surgery re-binds relocated flakes (and drops
//! removed ones) instead of sampling a dead handle — which keeps the
//! [`AdaptationHistory`] continuous across relocations.
//!
//! The policy also closes the scale-*in* half of the loop: containers
//! that stay underused get their flakes packed onto peers and their
//! VMs released (see the consolidation rung in [`elastic`]'s module
//! docs), with hysteresis so scale-out and scale-in never flutter.

pub mod elastic;

pub use elastic::{
    ElasticAction, ElasticDecision, ElasticityConfig, ElasticityPolicy,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::container::Container;
use crate::flake::{Flake, FlakeObservation};
use crate::util::time::Clock;
use crate::ALPHA;

/// A per-flake core-allocation policy.
pub trait AdaptationStrategy: Send {
    /// Desired core count given the latest observation at time `t`
    /// (seconds).  Return the current count for "no change".
    fn decide(&mut self, obs: &FlakeObservation, t: f64) -> usize;

    /// Strategy name for logs/CSV.
    fn name(&self) -> &'static str;
}

/// Profile of one pellet on the critical path, for the static plan.
#[derive(Debug, Clone)]
pub struct PelletProfile {
    pub id: String,
    /// Per-message processing latency with one instance, seconds (`l_i`).
    pub latency: f64,
    /// Output messages per input message (`s_i`).
    pub selectivity: f64,
}

/// Compute the static look-ahead allocation for a critical path.
///
/// `m1` messages arrive at the first pellet within each period `t`
/// seconds; `epsilon` is the user's latency tolerance.  Returns
/// `(pellet id, instances P_i, cores C_i)` per pellet.
pub fn static_plan(
    path: &[PelletProfile],
    m1: f64,
    t: f64,
    epsilon: f64,
    alpha: usize,
) -> Vec<(String, usize, usize)> {
    let mut out = Vec::with_capacity(path.len());
    let mut m_i = m1;
    for (i, p) in path.iter().enumerate() {
        if i > 0 {
            m_i *= path[i - 1].selectivity;
        }
        let p_i = ((p.latency * m_i) / (t + epsilon)).ceil().max(1.0);
        let c_i =
            ((p_i / alpha as f64).ceil() as usize).max(1);
        out.push((p.id.clone(), p_i as usize, c_i));
    }
    out
}

/// Fixed allocation from the static plan.
pub struct StaticLookAhead {
    pub cores: usize,
}

impl StaticLookAhead {
    /// Allocation for one pellet using the paper's formula.
    pub fn for_pellet(
        latency: f64,
        messages_per_period: f64,
        period: f64,
        epsilon: f64,
        alpha: usize,
    ) -> StaticLookAhead {
        let p = ((latency * messages_per_period) / (period + epsilon))
            .ceil()
            .max(1.0);
        StaticLookAhead {
            cores: ((p / alpha as f64).ceil() as usize).max(1),
        }
    }
}

impl AdaptationStrategy for StaticLookAhead {
    fn decide(&mut self, _obs: &FlakeObservation, _t: f64) -> usize {
        self.cores
    }

    fn name(&self) -> &'static str {
        "static"
    }
}

/// Algorithm 1: dynamic adaptation of cores for a flake.
pub struct DynamicStrategy {
    /// Relative threshold before scaling (e.g. 0.1 = 10%).
    pub threshold: f64,
    /// Instances granted per core (α).
    pub alpha: usize,
    /// Lower bound (0 lets an idle flake quiesce completely, as the
    /// paper's simulation shows for the dynamic strategy).
    pub min_cores: usize,
    pub max_cores: usize,
    /// Queue length that always forces a scale-up check.
    pub backlog_threshold: usize,
}

impl Default for DynamicStrategy {
    fn default() -> Self {
        DynamicStrategy {
            threshold: 0.10,
            alpha: ALPHA,
            min_cores: 0,
            max_cores: 64,
            backlog_threshold: 16,
        }
    }
}

impl DynamicStrategy {
    /// Messages/sec a given core count can sustain at the observed
    /// per-message latency.
    fn capacity(&self, cores: usize, latency: f64) -> f64 {
        if latency <= 0.0 {
            return f64::INFINITY;
        }
        (cores * self.alpha) as f64 / latency
    }
}

impl AdaptationStrategy for DynamicStrategy {
    fn decide(&mut self, obs: &FlakeObservation, _t: f64) -> usize {
        let cores = obs.cores;
        let latency = obs.service_latency;
        // Demand: what must be processed to keep up — arrivals plus a
        // drain term for any backlog.
        let demand = obs.arrival_rate
            + if obs.queue_len > self.backlog_threshold {
                obs.queue_len as f64 * 0.1 // drain backlog over ~10 samples
            } else {
                0.0
            };
        let cap_now = self.capacity(cores.max(1), latency);
        if demand > cap_now * (1.0 + self.threshold)
            || (cores == 0 && demand > 0.0)
        {
            return (cores + 1).min(self.max_cores);
        }
        // Scale down only if the reduced allocation still covers demand
        // (the second check in Algorithm 1, preventing fluctuation).
        if cores > self.min_cores {
            let cap_less = self.capacity(cores.saturating_sub(1), latency);
            let idle = demand <= 0.0 && obs.queue_len == 0;
            if idle
                || (demand < cap_less * (1.0 - self.threshold)
                    && obs.queue_len <= self.backlog_threshold)
            {
                return cores - 1;
            }
        }
        cores
    }

    fn name(&self) -> &'static str {
        "dynamic"
    }
}

/// Hybrid mode marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HybridMode {
    Static,
    Dynamic,
}

/// Hinted static allocation with a dynamic escape hatch.
pub struct HybridStrategy {
    /// Static allocation while the hint holds.
    pub static_cores: usize,
    /// Expected (hinted) average arrival rate, msg/s.
    pub expected_rate: f64,
    /// Relative deviation that triggers the switch to dynamic.
    pub deviation: f64,
    /// Queue length that must be reached again before switching back.
    pub settle_queue: usize,
    inner: DynamicStrategy,
    mode: HybridMode,
}

impl HybridStrategy {
    pub fn new(
        static_cores: usize,
        expected_rate: f64,
        deviation: f64,
    ) -> HybridStrategy {
        HybridStrategy {
            static_cores,
            expected_rate,
            deviation,
            settle_queue: 8,
            inner: DynamicStrategy::default(),
            mode: HybridMode::Static,
        }
    }

    /// Current mode, for tests and CSV annotation.
    pub fn is_dynamic(&self) -> bool {
        self.mode == HybridMode::Dynamic
    }
}

impl AdaptationStrategy for HybridStrategy {
    fn decide(&mut self, obs: &FlakeObservation, t: f64) -> usize {
        let rel_dev = if self.expected_rate > 0.0 {
            (obs.arrival_rate - self.expected_rate).abs()
                / self.expected_rate
        } else {
            if obs.arrival_rate > 0.0 {
                f64::INFINITY
            } else {
                0.0
            }
        };
        // An idle flake (no arrivals, empty queue) means the period's data
        // is fully processed — quiesce rather than treating the zero rate
        // as a deviation.  The paper notes hybrid "additionally quiesces
        // to 0 cores once done processing, like the dynamic strategy".
        if obs.arrival_rate <= 0.0 && obs.queue_len == 0 {
            self.mode = HybridMode::Static;
            return 0;
        }
        match self.mode {
            HybridMode::Static => {
                if rel_dev > self.deviation {
                    crate::log_debug!(
                        "hybrid: rate {:.1} deviates from hint {:.1}, \
                         switching to dynamic",
                        obs.arrival_rate,
                        self.expected_rate
                    );
                    self.mode = HybridMode::Dynamic;
                    self.inner.decide(obs, t)
                } else {
                    self.static_cores
                }
            }
            HybridMode::Dynamic => {
                if rel_dev <= self.deviation
                    && obs.queue_len <= self.settle_queue
                {
                    crate::log_debug!("hybrid: rate stabilized, back to static");
                    self.mode = HybridMode::Static;
                    self.static_cores
                } else {
                    self.inner.decide(obs, t)
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }
}

/// Resolves a pellet id to its *current* flake and hosting container.
///
/// The coordinator implements this over the live topology, so a
/// [`Monitor`] entry survives graph surgery: after a relocation the
/// lookup returns the replacement flake (re-bind), and after a removal
/// it returns `None` (the entry is dropped).
pub trait FlakeDirectory: Send + Sync {
    fn lookup(
        &self,
        pellet_id: &str,
    ) -> Option<(Arc<Flake>, Arc<Container>)>;

    /// Every pellet id currently in the dataflow.  [`Monitor`]s started
    /// with [`Monitor::start_auto`] poll this each tick so pellets
    /// added by later graph surgery come under adaptive control
    /// automatically (the entry set is no longer fixed at launch).
    fn pellet_ids(&self) -> Vec<String>;
}

/// Builds the adaptation strategy for a pellet id — used for the launch
/// set and for every pellet that graph surgery adds later.
pub type StrategyFactory =
    Box<dyn Fn(&str) -> Box<dyn AdaptationStrategy> + Send>;

/// One pellet under adaptive control: an id (resolved through the
/// [`FlakeDirectory`] each tick, never a pinned handle) plus its
/// strategy.
pub struct MonitoredEntry {
    pub pellet_id: String,
    pub strategy: Box<dyn AdaptationStrategy>,
}

/// One recorded monitor sample — the live-runtime analogue of the Fig. 4
/// simulator series.
#[derive(Debug, Clone)]
pub struct AdaptationSample {
    pub t: f64,
    pub pellet_id: String,
    pub strategy: &'static str,
    pub queue_len: usize,
    pub arrival_rate: f64,
    pub cores_before: usize,
    pub cores_after: usize,
}

/// Shared, append-only history of monitor decisions.
#[derive(Clone, Default)]
pub struct AdaptationHistory {
    samples: Arc<std::sync::Mutex<Vec<AdaptationSample>>>,
}

impl AdaptationHistory {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&self, s: AdaptationSample) {
        self.samples.lock().expect("history poisoned").push(s);
    }

    pub fn snapshot(&self) -> Vec<AdaptationSample> {
        self.samples.lock().expect("history poisoned").clone()
    }

    /// Export as CSV with the same columns as the Fig. 4 simulator series
    /// (plus pellet/strategy labels).
    pub fn to_csv(&self) -> crate::util::csv::CsvTable {
        let mut t = crate::util::csv::CsvTable::new(&[
            "t", "pellet", "strategy", "queue", "arrival_rate", "cores",
        ]);
        for s in self.snapshot() {
            t.push(vec![
                format!("{:.3}", s.t),
                s.pellet_id.clone(),
                s.strategy.to_string(),
                s.queue_len.to_string(),
                format!("{:.2}", s.arrival_rate),
                s.cores_after.to_string(),
            ]);
        }
        t
    }
}

/// Background monitor: samples flake probes at a fixed interval and applies
/// the strategies through the owning containers.
pub struct Monitor {
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
    history: AdaptationHistory,
}

impl Monitor {
    /// Start the monitor thread over a fixed entry set.  Every tick
    /// each entry's pellet id is re-resolved through `directory`, so
    /// the monitor always samples the *current* incarnation of a
    /// flake: a relocated flake is re-bound to its replacement (the
    /// history stays continuous) and a removed flake's entry is
    /// dropped instead of sampling a dead handle.
    pub fn start(
        entries: Vec<MonitoredEntry>,
        directory: Arc<dyn FlakeDirectory>,
        clock: Arc<dyn Clock>,
        interval: Duration,
    ) -> Monitor {
        Monitor::spawn(entries, None, directory, clock, interval)
    }

    /// As [`Monitor::start`], but the entry set is *discovered* from
    /// the directory each tick: every pellet currently in the dataflow
    /// is watched, including ones added by later graph surgery
    /// (`make` builds their strategies on first sight).  Removed
    /// pellets are dropped and never re-added.
    pub fn start_auto(
        make: StrategyFactory,
        directory: Arc<dyn FlakeDirectory>,
        clock: Arc<dyn Clock>,
        interval: Duration,
    ) -> Monitor {
        Monitor::spawn(Vec::new(), Some(make), directory, clock, interval)
    }

    fn spawn(
        entries: Vec<MonitoredEntry>,
        make: Option<StrategyFactory>,
        directory: Arc<dyn FlakeDirectory>,
        clock: Arc<dyn Clock>,
        interval: Duration,
    ) -> Monitor {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let history = AdaptationHistory::new();
        let history2 = history.clone();
        let join = thread::Builder::new()
            .name("floe-monitor".into())
            .spawn(move || {
                let mut entries = entries;
                // Mirror of the live entry ids so per-tick discovery
                // is O(1) per pellet, not a linear scan of entries.
                // Rebuilt after drops, so a removed-then-re-added id
                // is watched again like any other new pellet.
                let mut watched: std::collections::HashSet<String> =
                    entries.iter().map(|e| e.pellet_id.clone()).collect();
                while !stop2.load(Ordering::SeqCst) {
                    if let Some(make) = &make {
                        // Auto-watch: resolve the current pellet set
                        // from the shared topology and open an entry
                        // for every id not seen before (ROADMAP gap:
                        // the entry set used to be fixed at launch).
                        for id in directory.pellet_ids() {
                            if !watched.contains(&id) {
                                crate::log_info!(
                                    "monitor: watching new pellet '{id}'"
                                );
                                watched.insert(id.clone());
                                entries.push(MonitoredEntry {
                                    strategy: make(&id),
                                    pellet_id: id,
                                });
                            }
                        }
                    }
                    let t = clock.now();
                    let before = entries.len();
                    entries.retain_mut(|e| {
                        let Some((flake, container)) =
                            directory.lookup(&e.pellet_id)
                        else {
                            crate::log_info!(
                                "monitor: '{}' left the dataflow, \
                                 dropping entry",
                                e.pellet_id
                            );
                            return false;
                        };
                        let obs = flake.observe(t);
                        // Live flakes need >= 1 core to keep draining.
                        let want = e.strategy.decide(&obs, t).max(1);
                        if want != obs.cores {
                            if let Err(err) = container
                                .set_flake_cores(&e.pellet_id, want)
                            {
                                crate::log_warn!(
                                    "monitor: resize {} -> {want}: {err}",
                                    e.pellet_id
                                );
                            } else {
                                crate::log_debug!(
                                    "monitor[{}]: {} cores {} -> {want}",
                                    e.strategy.name(),
                                    e.pellet_id,
                                    obs.cores
                                );
                            }
                        }
                        history2.push(AdaptationSample {
                            t,
                            pellet_id: e.pellet_id.clone(),
                            strategy: e.strategy.name(),
                            queue_len: obs.queue_len,
                            arrival_rate: obs.arrival_rate,
                            cores_before: obs.cores,
                            cores_after: flake.cores(),
                        });
                        true
                    });
                    if entries.len() != before {
                        watched = entries
                            .iter()
                            .map(|e| e.pellet_id.clone())
                            .collect();
                    }
                    thread::sleep(interval);
                }
            })
            .expect("spawn monitor");
        Monitor { stop, join: Some(join), history }
    }

    /// The decision history recorded so far (live Fig. 4 series).
    pub fn history(&self) -> &AdaptationHistory {
        &self.history
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(
        queue: usize,
        arr: f64,
        lat: f64,
        cores: usize,
    ) -> FlakeObservation {
        FlakeObservation {
            queue_len: queue,
            arrival_rate: arr,
            completion_rate: 0.0,
            service_latency: lat,
            selectivity: 1.0,
            cores,
            instances: cores * ALPHA,
        }
    }

    #[test]
    fn static_plan_matches_formula() {
        // l=0.1s, 1200 msgs per 60s period, eps=20 -> P = ceil(120/80)=2,
        // C = ceil(2/4)=1.  Next pellet sees m*selectivity.
        let path = vec![
            PelletProfile {
                id: "a".into(),
                latency: 0.1,
                selectivity: 2.0,
            },
            PelletProfile {
                id: "b".into(),
                latency: 0.5,
                selectivity: 1.0,
            },
        ];
        let plan = static_plan(&path, 1200.0, 60.0, 20.0, 4);
        assert_eq!(plan[0], ("a".to_string(), 2, 1));
        // m2 = 2400, P = ceil(0.5*2400/80) = 15, C = ceil(15/4) = 4
        assert_eq!(plan[1], ("b".to_string(), 15, 4));
    }

    #[test]
    fn static_strategy_is_constant() {
        let mut s = StaticLookAhead { cores: 3 };
        assert_eq!(s.decide(&obs(100, 1000.0, 0.1, 1), 0.0), 3);
        assert_eq!(s.decide(&obs(0, 0.0, 0.1, 3), 1.0), 3);
    }

    #[test]
    fn dynamic_scales_up_under_load() {
        let mut d = DynamicStrategy::default();
        // capacity at 1 core = 4/0.1 = 40 msg/s; arrivals 100 -> scale up
        assert_eq!(d.decide(&obs(0, 100.0, 0.1, 1), 0.0), 2);
        // from 0 cores any demand scales up
        assert_eq!(d.decide(&obs(5, 1.0, 0.1, 0), 0.0), 1);
    }

    #[test]
    fn dynamic_scales_down_with_hysteresis() {
        let mut d = DynamicStrategy::default();
        // capacity at 3 cores = 120; at 2 cores = 80; arrivals 50 < 80*0.9
        // -> safe to drop one.
        assert_eq!(d.decide(&obs(0, 50.0, 0.1, 3), 0.0), 2);
        // arrivals 75 is within 10% of 80 -> hold (no flutter).
        assert_eq!(d.decide(&obs(0, 75.0, 0.1, 2), 0.0), 2);
        // idle -> quiesce toward min_cores
        assert_eq!(d.decide(&obs(0, 0.0, 0.1, 1), 0.0), 0);
    }

    #[test]
    fn dynamic_drains_backlog() {
        let mut d = DynamicStrategy::default();
        // low arrivals but big queue -> demand includes drain term
        let got = d.decide(&obs(1000, 10.0, 0.1, 1), 0.0);
        assert_eq!(got, 2);
    }

    #[test]
    fn hybrid_switches_modes() {
        let mut h = HybridStrategy::new(2, 100.0, 0.25);
        // near hint -> static cores
        assert_eq!(h.decide(&obs(0, 110.0, 0.01, 2), 0.0), 2);
        assert!(!h.is_dynamic());
        // spike -> dynamic takes over and scales
        let c = h.decide(&obs(500, 400.0, 0.05, 2), 1.0);
        assert!(h.is_dynamic());
        assert!(c >= 3, "cores {c}");
        // settle -> back to static
        let c = h.decide(&obs(0, 100.0, 0.01, c), 2.0);
        assert!(!h.is_dynamic());
        assert_eq!(c, 2);
        // idle -> quiesce to 0 like dynamic
        assert_eq!(h.decide(&obs(0, 0.0, 0.01, 2), 3.0), 0);
    }
}
