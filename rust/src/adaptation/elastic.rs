//! Closed-loop elasticity: from per-flake core regrants to
//! migration-based scale-out.
//!
//! The [`ElasticityPolicy`] consumes per-flake observations (live probe
//! samples or a deterministic model), asks the pellet's
//! [`AdaptationStrategy`] how many cores it wants, and acts on three
//! rungs:
//!
//! 1. **Regrant** — the wanted allocation fits the hosting container:
//!    grant it through [`crate::container::Container::set_flake_cores`]
//!    (the paper's §III in-container adaptation).
//! 2. **Saturation bridge** — the container cannot cover the want:
//!    grant whatever it still has and count the sample as *saturated*.
//! 3. **Relocate** — after [`ElasticityConfig::saturation_k`]
//!    consecutive saturated samples (and outside the post-move
//!    cooldown) the policy compiles a `RelocateFlake`
//!    [`GraphDelta`] and executes it through
//!    [`RunningDataflow::recompose`]: the engine quiesces the minimal
//!    pause set, hands state + buffered input to a replacement spawned
//!    via `ResourceManager::allocate_avoiding` on a *different*
//!    container, and resumes — zero message loss, per-producer FIFO.
//!    After the move the policy immediately grows the replacement
//!    toward the wanted allocation on its fresh container, and any
//!    container the move left empty is handed back to the cloud via
//!    [`crate::manager::ResourceManager::release_idle`] (the scale-in
//!    half of the loop: vacated VMs never leak).
//!
//! A relocation that fails — typically no capacity anywhere in the
//! cloud — **degrades** to the largest in-container regrant instead of
//! erroring, and is recorded as [`ElasticAction::Degraded`] so the
//! trace shows the unmet demand.
//!
//! 4. **Consolidate** (scale-*in*, the half of elasticity most systems
//!    skip): a container whose flakes' total grant stays at or below
//!    [`ElasticityConfig::underused_cores`] for
//!    [`ElasticityConfig::consolidate_k`] consecutive samples — with
//!    every hosted flake watched, unsaturated and outside its
//!    post-move cooldown — has its flakes *packed* onto peer
//!    containers through the same `RelocateFlake` → `recompose()`
//!    path (legal for TCP-fed flakes too, thanks to the logical
//!    endpoint layer), and the emptied VM is handed back to the cloud
//!    via `release_idle`.  Hysteresis keeps scale-out and scale-in
//!    from fluttering: every move (either direction) arms a
//!    consolidation cooldown and a per-flake cooldown, and a pack is
//!    attempted only when every victim flake provably fits on the
//!    peers that already exist (consolidation never provisions).
//!
//! Every control step appends one [`ElasticDecision`] to the decision
//! trace and one [`AdaptationSample`] to an [`AdaptationHistory`]; both
//! are pure functions of the observation stream, so a seeded workload
//! (see [`crate::sim::driver`]) makes the whole loop bit-reproducible
//! under `cargo test`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use super::{AdaptationHistory, AdaptationSample, AdaptationStrategy};
use crate::container::Container;
use crate::coordinator::RunningDataflow;
use crate::flake::{Flake, FlakeObservation};
use crate::recompose::{GraphDelta, RecomposeStats};

/// Elasticity knobs.
#[derive(Debug, Clone, Copy)]
pub struct ElasticityConfig {
    /// Consecutive saturated samples (wanted cores exceed what the
    /// hosting container can grant) before a relocation fires.
    pub saturation_k: usize,
    /// Control samples to hold off after a relocation, so the policy
    /// does not bounce a flake between containers while the replacement
    /// warms up.  Also arms the consolidation hysteresis after every
    /// move in either direction.
    pub cooldown: usize,
    /// Hard per-flake core ceiling (clamps the strategy's want).
    pub max_cores: usize,
    /// Consecutive samples a container must stay underused before its
    /// flakes are packed onto peers (scale-in).  0 disables
    /// consolidation.
    pub consolidate_k: usize,
    /// A container counts as underused when the cores granted to its
    /// flakes total at most this many.
    pub underused_cores: usize,
}

impl Default for ElasticityConfig {
    fn default() -> Self {
        ElasticityConfig {
            saturation_k: 3,
            cooldown: 10,
            max_cores: 64,
            consolidate_k: 4,
            underused_cores: 2,
        }
    }
}

/// What one control step did for one flake.
#[derive(Debug, Clone, PartialEq)]
pub enum ElasticAction {
    /// Allocation already matches demand (or nothing could change).
    Hold,
    /// Cores regranted within the hosting container.
    Regrant { from: usize, to: usize },
    /// Container saturated for `saturation_k` samples: the flake was
    /// migrated to another container via `recompose()`.
    Relocate { wanted: usize },
    /// Relocation was due but could not be placed (no capacity); the
    /// policy fell back to the largest grant the container covers.
    Degraded { wanted: usize, granted: usize },
    /// Scale-in: the flake was packed onto a peer container because
    /// its host stayed underused for `consolidate_k` samples; the
    /// emptied host's VM is released afterwards.
    Consolidate { from: String, to: String },
}

/// One entry of the decision trace.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticDecision {
    pub t: f64,
    pub pellet_id: String,
    pub action: ElasticAction,
}

/// Internal plan produced by the pure decision step.
enum Planned {
    Hold,
    Regrant { to: usize },
    Relocate { wanted: usize },
}

struct Watched {
    pellet_id: String,
    strategy: Box<dyn AdaptationStrategy>,
    saturated_streak: usize,
    cooldown_left: usize,
    /// Wall-clock start of the current saturation streak; consumed by
    /// the relocation that resolves it to record time-to-react.
    saturation_since: Option<Instant>,
}

/// The closed-loop elasticity controller (see module docs).
pub struct ElasticityPolicy {
    cfg: ElasticityConfig,
    watched: Vec<Watched>,
    trace: Vec<ElasticDecision>,
    history: AdaptationHistory,
    relocation_stats: Vec<RecomposeStats>,
    consolidation_stats: Vec<RecomposeStats>,
    /// Consecutive underused samples per container id.
    container_streaks: BTreeMap<String, usize>,
    /// Hysteresis: samples to hold off before the next consolidation
    /// pass (armed by every move in either direction).
    consolidate_cooldown: usize,
}

impl ElasticityPolicy {
    pub fn new(cfg: ElasticityConfig) -> ElasticityPolicy {
        ElasticityPolicy {
            cfg,
            watched: Vec::new(),
            trace: Vec::new(),
            history: AdaptationHistory::new(),
            relocation_stats: Vec::new(),
            consolidation_stats: Vec::new(),
            container_streaks: BTreeMap::new(),
            consolidate_cooldown: 0,
        }
    }

    /// Build a policy from the launch-wide
    /// [`crate::coordinator::RuntimeOptions`] — the one place every
    /// runtime knob now lives — so elasticity runs share their
    /// configuration source with the launch itself.
    pub fn from_options(
        options: &crate::coordinator::RuntimeOptions,
    ) -> ElasticityPolicy {
        ElasticityPolicy::new(options.elasticity)
    }

    /// Put a pellet under elastic control.
    pub fn watch(
        &mut self,
        pellet_id: &str,
        strategy: Box<dyn AdaptationStrategy>,
    ) {
        self.watched.push(Watched {
            pellet_id: pellet_id.to_string(),
            strategy,
            saturated_streak: 0,
            cooldown_left: 0,
            saturation_since: None,
        });
    }

    /// The decision trace so far (one entry per pellet per step).
    pub fn trace(&self) -> &[ElasticDecision] {
        &self.trace
    }

    /// Per-step samples in the same shape the [`super::Monitor`]
    /// records, so elasticity runs export the live Fig. 4 series too.
    pub fn history(&self) -> &AdaptationHistory {
        &self.history
    }

    /// Engine stats of every relocation this policy initiated
    /// (downtime per scale-out).
    pub fn relocations(&self) -> &[RecomposeStats] {
        &self.relocation_stats
    }

    /// Engine stats of every scale-in packing move this policy
    /// initiated (downtime per consolidation).
    pub fn consolidations(&self) -> &[RecomposeStats] {
        &self.consolidation_stats
    }

    /// One live control step: observe every watched flake through its
    /// real probes, decide, apply.
    pub fn step_live(
        &mut self,
        run: &RunningDataflow,
        t: f64,
    ) -> Vec<ElasticDecision> {
        self.step_with(run, t, |_, f| f.observe(t))
    }

    /// One control step with caller-supplied observations — the
    /// deterministic harness passes modeled observations here while the
    /// *actions* still execute against the live dataflow.
    pub fn step_with(
        &mut self,
        run: &RunningDataflow,
        t: f64,
        observe: impl Fn(&str, &Flake) -> FlakeObservation,
    ) -> Vec<ElasticDecision> {
        let ids: Vec<String> =
            self.watched.iter().map(|w| w.pellet_id.clone()).collect();
        let mut out = Vec::new();
        for id in ids {
            let (Ok(flake), Ok(container)) =
                (run.flake(&id), run.container(&id))
            else {
                continue; // pellet left the graph; skip this step
            };
            let obs = observe(&id, &flake);
            let planned = self.plan(&id, &obs, container.free_cores(), t);
            let action = self.apply(run, &id, &obs, planned, &container);
            let after =
                run.flake(&id).map(|f| f.cores()).unwrap_or(obs.cores);
            self.history.push(AdaptationSample {
                t,
                pellet_id: id.clone(),
                strategy: self.strategy_name(&id),
                queue_len: obs.queue_len,
                arrival_rate: obs.arrival_rate,
                cores_before: obs.cores,
                cores_after: after,
            });
            crate::telemetry::ctr_elasticity_decision(decision_kind(
                &action,
            ))
            .inc();
            let decision = ElasticDecision { t, pellet_id: id, action };
            self.trace.push(decision.clone());
            out.push(decision);
        }
        if self.cfg.consolidate_k > 0 {
            self.consolidate(run, t, &mut out);
        }
        out
    }

    /// Pure decision for one pellet: wanted cores from the strategy,
    /// then the saturation rule against the container's spare budget.
    /// Mutates only the per-pellet streak/cooldown counters, so the
    /// decision sequence is a function of the observation sequence.
    fn plan(
        &mut self,
        pellet_id: &str,
        obs: &FlakeObservation,
        container_free: usize,
        t: f64,
    ) -> Planned {
        let max_cores = self.cfg.max_cores.max(1);
        let Some(w) =
            self.watched.iter_mut().find(|w| w.pellet_id == pellet_id)
        else {
            return Planned::Hold;
        };
        let wanted = w.strategy.decide(obs, t).clamp(1, max_cores);
        // What this container could grant right now: the current
        // allocation plus every unclaimed core on the host.
        let available = obs.cores + container_free;
        if w.cooldown_left > 0 {
            w.cooldown_left -= 1;
        }
        if wanted > available {
            w.saturated_streak += 1;
            if w.saturation_since.is_none() {
                w.saturation_since = Some(Instant::now());
            }
            if w.saturated_streak >= self.cfg.saturation_k
                && w.cooldown_left == 0
            {
                w.saturated_streak = 0;
                w.cooldown_left = self.cfg.cooldown;
                return Planned::Relocate { wanted };
            }
            // Saturation bridge: take what the container still has.
            if available > obs.cores {
                return Planned::Regrant { to: available };
            }
            return Planned::Hold;
        }
        w.saturated_streak = 0;
        w.saturation_since = None;
        if wanted != obs.cores {
            Planned::Regrant { to: wanted }
        } else {
            Planned::Hold
        }
    }

    /// Execute a planned action against the live dataflow.
    fn apply(
        &mut self,
        run: &RunningDataflow,
        pellet_id: &str,
        obs: &FlakeObservation,
        planned: Planned,
        container: &Arc<Container>,
    ) -> ElasticAction {
        match planned {
            Planned::Hold => ElasticAction::Hold,
            Planned::Regrant { to } => {
                // Record what actually happened: a lost race with a
                // co-hosted flake's grant turns the step into a Hold,
                // not a phantom regrant in the trace.
                match container.set_flake_cores(pellet_id, to) {
                    Ok(()) => {
                        ElasticAction::Regrant { from: obs.cores, to }
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "elastic: regrant {pellet_id} -> {to}: {e}"
                        );
                        ElasticAction::Hold
                    }
                }
            }
            Planned::Relocate { wanted } => {
                let mut delta = GraphDelta::against(&run.graph());
                delta.relocate_flake(pellet_id);
                match run.recompose(&delta) {
                    Ok(stats) => {
                        crate::log_info!(
                            "elastic: relocated {pellet_id} \
                             (downtime {:.2} ms)",
                            stats.downtime_ms
                        );
                        self.relocation_stats.push(stats);
                        // Time-to-react: saturation onset to the
                        // moment the replacement is live.
                        if let Some(since) = self
                            .watched
                            .iter_mut()
                            .find(|w| w.pellet_id == pellet_id)
                            .and_then(|w| w.saturation_since.take())
                        {
                            crate::telemetry::hist_elasticity_react()
                                .record(
                                    since.elapsed().as_nanos() as u64,
                                );
                        }
                        // Grow into the fresh container immediately.
                        if let (Ok(flake), Ok(new_home)) = (
                            run.flake(pellet_id),
                            run.container(pellet_id),
                        ) {
                            let to = wanted.min(
                                flake.cores() + new_home.free_cores(),
                            );
                            if to != flake.cores() {
                                if let Err(e) = new_home
                                    .set_flake_cores(pellet_id, to)
                                {
                                    crate::log_warn!(
                                        "elastic: post-move grant \
                                         {pellet_id} -> {to}: {e}"
                                    );
                                }
                            }
                        }
                        // Scale-in half of the move: if the relocation
                        // (plus any earlier consolidation) left a
                        // container empty, hand its VM back to the
                        // cloud instead of leaking it.  Goes through
                        // the gated RunningDataflow entry point so it
                        // can never race a concurrent surgery's
                        // allocate-then-spawn window.
                        match run.release_idle_containers() {
                            Ok(0) => {}
                            Ok(n) => crate::log_info!(
                                "elastic: released {n} idle \
                                 container(s) after relocating \
                                 {pellet_id}"
                            ),
                            Err(e) => crate::log_warn!(
                                "elastic: release_idle after \
                                 relocating {pellet_id}: {e}"
                            ),
                        }
                        // Anti-flutter: a scale-out move re-arms the
                        // scale-in hysteresis and invalidates every
                        // container's underuse streak (the placement
                        // just changed under them).
                        self.container_streaks.clear();
                        self.consolidate_cooldown = self.cfg.cooldown;
                        ElasticAction::Relocate { wanted }
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "elastic: relocation of {pellet_id} \
                             failed ({e}); degrading to in-container \
                             regrant"
                        );
                        let mut granted = wanted
                            .min(obs.cores + container.free_cores());
                        if granted > obs.cores
                            && container
                                .set_flake_cores(pellet_id, granted)
                                .is_err()
                        {
                            granted = obs.cores; // record reality
                        }
                        ElasticAction::Degraded { wanted, granted }
                    }
                }
            }
        }
    }

    /// The scale-in pass (module docs, rung 4): detect containers
    /// that stayed underused for `consolidate_k` consecutive samples,
    /// pack their flakes onto existing peers via `RelocateFlake`
    /// deltas — legal for TCP-fed flakes too, since endpoints are
    /// logical — and release the emptied VMs.  Consolidation never
    /// provisions: a pack is attempted only when every victim flake
    /// provably fits on the peers that already exist.
    fn consolidate(
        &mut self,
        run: &RunningDataflow,
        t: f64,
        out: &mut Vec<ElasticDecision>,
    ) {
        if self.consolidate_cooldown > 0 {
            self.consolidate_cooldown -= 1;
            return;
        }
        let containers = run.manager().containers();
        let mut ripe: Vec<Arc<Container>> = Vec::new();
        for c in &containers {
            let ids = c.flake_ids();
            let used = c.total_cores().saturating_sub(c.free_cores());
            // Underused and safe to drain: every hosted flake is under
            // elastic control, unsaturated, and settled after any
            // earlier move.  Containers hosting unwatched pellets
            // (sources, sinks) are never drained out from under them.
            let eligible = !ids.is_empty()
                && used <= self.cfg.underused_cores
                && ids.iter().all(|id| {
                    self.watched.iter().any(|w| {
                        w.pellet_id == *id
                            && w.saturated_streak == 0
                            && w.cooldown_left == 0
                    })
                });
            let streak =
                self.container_streaks.entry(c.id.clone()).or_insert(0);
            if eligible {
                *streak += 1;
                if *streak >= self.cfg.consolidate_k {
                    ripe.push(Arc::clone(c));
                }
            } else {
                *streak = 0;
            }
        }
        if containers.len() < 2 {
            return;
        }
        // Deterministic victim: the least-used ripe container (id as
        // tie-break).
        let Some(victim) = ripe.into_iter().min_by_key(|c| {
            (c.total_cores() - c.free_cores(), c.id.clone())
        }) else {
            return;
        };
        // Feasibility: every victim flake must fit on an existing
        // peer (largest first, greedy) — otherwise packing would
        // provision a fresh VM and turn scale-in into scale-out.
        let mut peer_free: Vec<usize> = containers
            .iter()
            .filter(|c| c.id != victim.id)
            .map(|c| c.free_cores())
            .collect();
        let mut moves: Vec<(String, usize)> = victim
            .flake_ids()
            .into_iter()
            .filter_map(|id| {
                victim.flake(&id).map(|f| (id, f.cores()))
            })
            .collect();
        moves.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for (_, cores) in &moves {
            match peer_free
                .iter_mut()
                .filter(|free| **free >= *cores)
                .min()
            {
                Some(slot) => *slot -= cores,
                None => return, // not packable today; streak persists
            }
        }
        // Pack.  The engine's best-fit `allocate_avoiding` places each
        // flake on the fullest peer with room, skipping the victim.
        for (id, _) in moves {
            let mut delta = GraphDelta::against(&run.graph());
            delta.relocate_flake(&id);
            match run.recompose(&delta) {
                Ok(stats) => {
                    let to = run
                        .container(&id)
                        .map(|c| c.id.clone())
                        .unwrap_or_default();
                    crate::log_info!(
                        "elastic: consolidated {id}: {} -> {to} \
                         (downtime {:.2} ms)",
                        victim.id,
                        stats.downtime_ms
                    );
                    self.consolidation_stats.push(stats);
                    crate::telemetry::ctr_elasticity_decision(
                        "consolidate",
                    )
                    .inc();
                    crate::telemetry::tracelog().instant(
                        "consolidate",
                        &id,
                        &format!("{} -> {to}", victim.id),
                    );
                    if let Some(w) = self
                        .watched
                        .iter_mut()
                        .find(|w| w.pellet_id == id)
                    {
                        w.cooldown_left = self.cfg.cooldown;
                    }
                    let decision = ElasticDecision {
                        t,
                        pellet_id: id,
                        action: ElasticAction::Consolidate {
                            from: victim.id.clone(),
                            to,
                        },
                    };
                    self.trace.push(decision.clone());
                    out.push(decision);
                }
                Err(e) => {
                    crate::log_warn!(
                        "elastic: consolidation of {id} off {} \
                         failed: {e}",
                        victim.id
                    );
                    break;
                }
            }
        }
        // Hand the emptied VM(s) back and arm the hysteresis window.
        match run.release_idle_containers() {
            Ok(0) => {}
            Ok(n) => crate::log_info!(
                "elastic: released {n} idle container(s) after \
                 consolidating {}",
                victim.id
            ),
            Err(e) => crate::log_warn!(
                "elastic: release_idle after consolidating {}: {e}",
                victim.id
            ),
        }
        self.container_streaks.clear();
        self.consolidate_cooldown = self.cfg.cooldown;
    }

    fn strategy_name(&self, pellet_id: &str) -> &'static str {
        self.watched
            .iter()
            .find(|w| w.pellet_id == pellet_id)
            .map(|w| w.strategy.name())
            .unwrap_or("elastic")
    }
}

/// Metric label for `floe_elasticity_decisions_total{kind=...}`.
fn decision_kind(action: &ElasticAction) -> &'static str {
    match action {
        ElasticAction::Hold => "hold",
        ElasticAction::Regrant { .. } => "regrant",
        ElasticAction::Relocate { .. } => "relocate",
        ElasticAction::Degraded { .. } => "degraded",
        ElasticAction::Consolidate { .. } => "consolidate",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptation::StaticLookAhead;
    use crate::ALPHA;

    fn obs(cores: usize) -> FlakeObservation {
        FlakeObservation {
            queue_len: 0,
            arrival_rate: 0.0,
            completion_rate: 0.0,
            service_latency: 0.1,
            selectivity: 1.0,
            cores,
            instances: cores * ALPHA,
        }
    }

    fn policy(k: usize, cooldown: usize) -> ElasticityPolicy {
        let mut p = ElasticityPolicy::new(ElasticityConfig {
            saturation_k: k,
            cooldown,
            max_cores: 16,
            consolidate_k: 0,
            underused_cores: 2,
        });
        // Oracle strategy that always wants 10 cores.
        p.watch("hot", Box::new(StaticLookAhead { cores: 10 }));
        p
    }

    #[test]
    fn saturation_streak_triggers_relocation() {
        let mut p = policy(3, 5);
        // Container has nothing spare: wanted 10 > available 2.
        for i in 0..2 {
            match p.plan("hot", &obs(2), 0, i as f64) {
                Planned::Hold => {}
                _ => panic!("relocated before k samples"),
            }
        }
        match p.plan("hot", &obs(2), 0, 2.0) {
            Planned::Relocate { wanted } => assert_eq!(wanted, 10),
            _ => panic!("expected relocation on sample k"),
        }
    }

    #[test]
    fn cooldown_blocks_immediate_rerelocation() {
        let mut p = policy(1, 4);
        assert!(matches!(
            p.plan("hot", &obs(2), 0, 0.0),
            Planned::Relocate { .. }
        ));
        // Cooldown 4: the next 3 saturated samples only bridge/hold.
        for i in 1..4 {
            assert!(
                !matches!(
                    p.plan("hot", &obs(2), 0, i as f64),
                    Planned::Relocate { .. }
                ),
                "relocated during cooldown (sample {i})"
            );
        }
        assert!(matches!(
            p.plan("hot", &obs(2), 0, 4.0),
            Planned::Relocate { .. }
        ));
    }

    #[test]
    fn unsaturated_want_is_a_plain_regrant() {
        let mut p = policy(3, 5);
        // 8 free cores: wanted 10 fits (2 + 8) -> regrant to 10.
        match p.plan("hot", &obs(2), 8, 0.0) {
            Planned::Regrant { to } => assert_eq!(to, 10),
            _ => panic!("expected regrant"),
        }
        // Already at 10 -> hold.
        assert!(matches!(p.plan("hot", &obs(10), 2, 1.0), Planned::Hold));
    }

    #[test]
    fn saturation_bridge_takes_partial_grant() {
        let mut p = policy(5, 5);
        // wanted 10 > available 2 + 3 = 5 -> saturated, but the spare 3
        // cores are still granted as a bridge.
        match p.plan("hot", &obs(2), 3, 0.0) {
            Planned::Regrant { to } => assert_eq!(to, 5),
            _ => panic!("expected bridge regrant"),
        }
    }
}
