//! Messages flowing on Floe data channels.
//!
//! Messages are small serialized objects or large payloads (§II-A).  Payloads
//! are reference-counted so the *duplicate* split pattern (Fig. 1, P7) clones
//! envelopes, not bytes.  A message optionally carries a routing `key`
//! (dynamic key-hash port mapping — the streaming MapReduce shuffle) and a
//! `landmark` marker ("landmark" window delimiters and "update landmark"
//! notifications from dynamic task updates).
//!
//! The binary framing here is the wire format of the TCP transport in
//! [`crate::channel`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{FloeError, Result};

/// Message payload variants.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Control-only message (landmarks often carry no data).
    Empty,
    /// UTF-8 text (posts, CSV lines, XML documents).
    Text(Arc<str>),
    /// Opaque bytes (serialized objects, file chunks).
    Bytes(Arc<[u8]>),
    /// Dense f32 vector (feature vectors handed to the XLA kernels).
    F32s(Arc<Vec<f32>>),
    /// Port-name-indexed tuple produced by a synchronous merge (Fig. 1, P5).
    Tuple(Arc<BTreeMap<String, Message>>),
}

/// Landmark markers (§II-A / §II-B).
#[derive(Debug, Clone, PartialEq)]
pub enum Landmark {
    /// End of a logical message window, e.g. so streaming reducers emit
    /// their aggregate.
    WindowEnd(String),
    /// Notification that an upstream pellet's logic changed in-place.
    Update { version: u64 },
    /// Graph-surgery cut marker (see [`crate::recompose`]), carrying
    /// the new graph version.  Scope matches the channel ordering
    /// contract: within one producer's stream, messages before the
    /// marker flowed on the pre-recomposition wiring and messages
    /// after it on the new topology.  Delivery is best-effort — a
    /// full queue drops the marker rather than blocking the engine —
    /// so consumers must treat it as a hint, not a barrier.
    Recompose { version: u64 },
    /// Application-defined marker.
    Custom(String),
}

static SEQ: AtomicU64 = AtomicU64::new(1);

/// A message envelope.
///
/// The envelope itself is `Arc`-backed end to end: the payload variants
/// share their bytes and the routing `key` is an `Arc<str>`, so the
/// duplicate split and landmark broadcasts clone reference counts, not
/// data.
#[derive(Debug)]
pub struct Message {
    pub payload: Payload,
    /// Routing key for the key-hash split (MapReduce shuffle).
    /// `Arc`-backed so fan-out clones share the allocation.
    pub key: Option<Arc<str>>,
    /// Landmark marker, if this is a control message.
    pub landmark: Option<Landmark>,
    /// Creation timestamp, microseconds since process start (end-to-end
    /// latency accounting).
    pub created_us: u64,
    /// Process-wide sequence number (monotone, for ordering diagnostics).
    pub seq: u64,
    /// Lazily cached FNV-1a hash of the routing key (0 = not yet
    /// computed; see [`Message::route_hash`]).  Clones inherit the
    /// cache; equality ignores it.
    key_hash: AtomicU64,
}

impl Clone for Message {
    fn clone(&self) -> Message {
        Message {
            payload: self.payload.clone(),
            key: self.key.clone(),
            landmark: self.landmark.clone(),
            created_us: self.created_us,
            seq: self.seq,
            key_hash: AtomicU64::new(self.key_hash.load(Ordering::Relaxed)),
        }
    }
}

impl PartialEq for Message {
    fn eq(&self, other: &Message) -> bool {
        self.payload == other.payload
            && self.key == other.key
            && self.landmark == other.landmark
            && self.created_us == other.created_us
            && self.seq == other.seq
    }
}

pub(crate) fn now_us() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_micros() as u64
}

impl Message {
    fn with_payload(payload: Payload) -> Message {
        Message {
            payload,
            key: None,
            landmark: None,
            created_us: now_us(),
            seq: SEQ.fetch_add(1, Ordering::Relaxed),
            key_hash: AtomicU64::new(0),
        }
    }

    /// Empty control message.
    pub fn empty() -> Message {
        Message::with_payload(Payload::Empty)
    }

    /// Text message.
    pub fn text(s: impl Into<String>) -> Message {
        Message::with_payload(Payload::Text(Arc::from(s.into())))
    }

    /// Byte message.
    pub fn bytes(b: impl Into<Vec<u8>>) -> Message {
        Message::with_payload(Payload::Bytes(Arc::from(
            b.into().into_boxed_slice(),
        )))
    }

    /// Dense f32 vector message.
    pub fn f32s(v: Vec<f32>) -> Message {
        Message::with_payload(Payload::F32s(Arc::new(v)))
    }

    /// Tuple message from a synchronous merge.
    pub fn tuple(map: BTreeMap<String, Message>) -> Message {
        Message::with_payload(Payload::Tuple(Arc::new(map)))
    }

    /// Landmark control message.
    pub fn landmark(l: Landmark) -> Message {
        let mut m = Message::empty();
        m.landmark = Some(l);
        m
    }

    /// Set the routing key (builder style).
    pub fn with_key(mut self, key: impl Into<Arc<str>>) -> Message {
        self.key = Some(key.into());
        self.key_hash.store(0, Ordering::Relaxed);
        self
    }

    /// The routing hash of this message: FNV-1a of the `key` (falling
    /// back to the text payload, then to the empty string — the same
    /// derivation the key-hash split has always used), computed once
    /// and cached so repeated key-hash hops stop re-hashing the string.
    ///
    /// The cache assumes `key` is not mutated after the message starts
    /// routing, which holds for every runtime path (messages are
    /// logically immutable once emitted).
    pub fn route_hash(&self) -> u64 {
        let cached = self.key_hash.load(Ordering::Relaxed);
        if cached != 0 {
            return cached;
        }
        let h = match (&self.key, self.as_text()) {
            (Some(k), _) => key_hash(k),
            (None, Some(t)) => key_hash(t),
            (None, None) => key_hash(""),
        };
        // 0 marks "unset"; FNV-1a yields 0 only with negligible
        // probability, and remapping merely costs a redundant rehash
        // elsewhere, never a routing divergence.
        let h = if h == 0 { key_hash("\u{0}") } else { h };
        self.key_hash.store(h, Ordering::Relaxed);
        h
    }

    pub fn is_landmark(&self) -> bool {
        self.landmark.is_some()
    }

    /// Text payload if present.
    pub fn as_text(&self) -> Option<&str> {
        match &self.payload {
            Payload::Text(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f32s(&self) -> Option<&[f32]> {
        match &self.payload {
            Payload::F32s(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match &self.payload {
            Payload::Bytes(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_tuple(&self) -> Option<&BTreeMap<String, Message>> {
        match &self.payload {
            Payload::Tuple(t) => Some(t),
            _ => None,
        }
    }

    /// Age of this message in seconds (for latency metrics).
    pub fn age_secs(&self) -> f64 {
        (now_us().saturating_sub(self.created_us)) as f64 / 1e6
    }

    // --- wire format ------------------------------------------------------

    /// Serialize to the TCP wire format into a fresh buffer.  Hot paths
    /// should prefer [`Message::encode_into`] with a reused buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        self.encode_into(&mut out);
        out
    }

    /// Serialize to the TCP wire format, appending to `out` — the
    /// zero-alloc half of the wire API: framing layers (see
    /// [`crate::channel::TcpSender`]) encode straight into a reusable
    /// per-connection scratch buffer instead of allocating per message.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.created_us.to_le_bytes());
        match &self.key {
            None => out.push(0),
            Some(k) => {
                out.push(1);
                put_str(out, k);
            }
        }
        match &self.landmark {
            None => out.push(0),
            Some(Landmark::WindowEnd(s)) => {
                out.push(1);
                put_str(out, s);
            }
            Some(Landmark::Update { version }) => {
                out.push(2);
                out.extend_from_slice(&version.to_le_bytes());
            }
            Some(Landmark::Custom(s)) => {
                out.push(3);
                put_str(out, s);
            }
            Some(Landmark::Recompose { version }) => {
                out.push(4);
                out.extend_from_slice(&version.to_le_bytes());
            }
        }
        match &self.payload {
            Payload::Empty => out.push(0),
            Payload::Text(s) => {
                out.push(1);
                put_bytes(out, s.as_bytes());
            }
            Payload::Bytes(b) => {
                out.push(2);
                put_bytes(out, b);
            }
            Payload::F32s(v) => {
                out.push(3);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for f in v.iter() {
                    out.extend_from_slice(&f.to_le_bytes());
                }
            }
            Payload::Tuple(map) => {
                out.push(4);
                out.extend_from_slice(&(map.len() as u16).to_le_bytes());
                for (k, m) in map.iter() {
                    put_str(out, k);
                    m.encode_into(out);
                }
            }
        }
    }

    /// Deserialize from the TCP wire format.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut cur = Cursor { buf, pos: 0 };
        let m = Message::decode_from(&mut cur)?;
        if cur.pos != buf.len() {
            return Err(FloeError::Parse("message: trailing bytes".into()));
        }
        Ok(m)
    }

    fn decode_from(c: &mut Cursor) -> Result<Message> {
        let seq = c.u64()?;
        let created_us = c.u64()?;
        let key = match c.u8()? {
            0 => None,
            1 => Some(Arc::<str>::from(c.string()?)),
            t => {
                return Err(FloeError::Parse(format!(
                    "message: bad key tag {t}"
                )))
            }
        };
        let landmark = match c.u8()? {
            0 => None,
            1 => Some(Landmark::WindowEnd(c.string()?)),
            2 => Some(Landmark::Update { version: c.u64()? }),
            3 => Some(Landmark::Custom(c.string()?)),
            4 => Some(Landmark::Recompose { version: c.u64()? }),
            t => {
                return Err(FloeError::Parse(format!(
                    "message: bad landmark tag {t}"
                )))
            }
        };
        let payload = match c.u8()? {
            0 => Payload::Empty,
            1 => {
                let b = c.bytes()?;
                Payload::Text(Arc::from(String::from_utf8(b).map_err(
                    |_| FloeError::Parse("message: invalid utf8".into()),
                )?))
            }
            2 => Payload::Bytes(Arc::from(c.bytes()?.into_boxed_slice())),
            3 => {
                let n = c.u32()? as usize;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(f32::from_le_bytes(c.array::<4>()?));
                }
                Payload::F32s(Arc::new(v))
            }
            4 => {
                let n = c.u16()? as usize;
                let mut map = BTreeMap::new();
                for _ in 0..n {
                    let k = c.string()?;
                    map.insert(k, Message::decode_from(c)?);
                }
                Payload::Tuple(Arc::new(map))
            }
            t => {
                return Err(FloeError::Parse(format!(
                    "message: bad payload tag {t}"
                )))
            }
        };
        Ok(Message {
            payload,
            key,
            landmark,
            created_us,
            seq,
            key_hash: AtomicU64::new(0),
        })
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_bytes(out, s.as_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        if self.pos + N > self.buf.len() {
            return Err(FloeError::Parse("message: truncated".into()));
        }
        let mut a = [0u8; N];
        a.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(a)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.array::<1>()?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.array::<2>()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array::<4>()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array::<8>()?))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if self.pos + n > self.buf.len() {
            return Err(FloeError::Parse("message: truncated".into()));
        }
        let v = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?)
            .map_err(|_| FloeError::Parse("message: invalid utf8".into()))
    }
}

/// FNV-1a hash of a routing key — the "hash on the key" of the dynamic port
/// mapping (§II-A).  Stable across processes so distributed shuffles agree.
pub fn key_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = Message::text("hello");
        assert_eq!(t.as_text(), Some("hello"));
        assert!(t.as_f32s().is_none());
        let f = Message::f32s(vec![1.0, 2.0]);
        assert_eq!(f.as_f32s(), Some(&[1.0f32, 2.0][..]));
        let b = Message::bytes(vec![1, 2, 3]);
        assert_eq!(b.as_bytes(), Some(&[1u8, 2, 3][..]));
        assert!(Message::empty().key.is_none());
    }

    #[test]
    fn seq_is_monotonic() {
        let a = Message::empty();
        let b = Message::empty();
        assert!(b.seq > a.seq);
    }

    #[test]
    fn clone_shares_payload() {
        let m = Message::f32s(vec![0.0; 1024]);
        let c = m.clone();
        if let (Payload::F32s(a), Payload::F32s(b)) = (&m.payload, &c.payload)
        {
            assert!(Arc::ptr_eq(a, b));
        } else {
            panic!("expected f32 payloads");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut map = BTreeMap::new();
        map.insert("a".to_string(), Message::text("x").with_key("k1"));
        map.insert("b".to_string(), Message::f32s(vec![1.5, -2.5]));
        let cases = vec![
            Message::empty(),
            Message::text("héllo wörld"),
            Message::bytes(vec![0, 255, 128]),
            Message::f32s(vec![f32::MIN, 0.0, f32::MAX]),
            Message::tuple(map),
            Message::landmark(Landmark::WindowEnd("w1".into())),
            Message::landmark(Landmark::Update { version: 7 }),
            Message::landmark(Landmark::Recompose { version: 3 }),
            Message::landmark(Landmark::Custom("mark".into())),
            Message::text("keyed").with_key("route-me"),
        ];
        for m in cases {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn decode_rejects_truncation_and_garbage() {
        let enc = Message::text("hello").encode();
        for cut in [0, 1, enc.len() / 2, enc.len() - 1] {
            assert!(Message::decode(&enc[..cut]).is_err(), "cut={cut}");
        }
        let mut bad = enc.clone();
        bad.push(0); // trailing byte
        assert!(Message::decode(&bad).is_err());
        let mut badtag = enc;
        badtag[17] = 99; // landmark tag byte: seq(8)+ts(8)+keytag(1)
        assert!(Message::decode(&badtag).is_err());
    }

    #[test]
    fn route_hash_matches_key_hash_and_caches() {
        let m = Message::text("v").with_key("abc");
        assert_eq!(m.route_hash(), key_hash("abc"));
        assert_eq!(m.route_hash(), key_hash("abc")); // cached path
        // Fallbacks: text payload, then the empty string.
        assert_eq!(Message::text("t").route_hash(), key_hash("t"));
        assert_eq!(Message::empty().route_hash(), key_hash(""));
        // Clones share the key allocation and the cached hash.
        let c = m.clone();
        match (&m.key, &c.key) {
            (Some(a), Some(b)) => assert!(Arc::ptr_eq(a, b)),
            _ => panic!("expected keys"),
        }
        assert_eq!(c.route_hash(), key_hash("abc"));
    }

    #[test]
    fn key_hash_stable_and_spread() {
        assert_eq!(key_hash("abc"), key_hash("abc"));
        assert_ne!(key_hash("abc"), key_hash("abd"));
        let r = 4u64;
        let mut counts = [0usize; 4];
        for i in 0..1000 {
            counts[(key_hash(&format!("key-{i}")) % r) as usize] += 1;
        }
        for c in counts {
            assert!(c > 150, "skewed shuffle: {counts:?}");
        }
    }
}
