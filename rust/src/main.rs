//! Floe CLI: launch dataflows, run the case studies, and regenerate the
//! paper's simulation study.
//!
//! ```text
//! floe run <graph.xml> [--serve PORT]      launch an XML graph (builtins)
//! floe simulate [--profile P] [--strategy S] [--out DIR] [--duration S]
//! floe pipeline [--events N]               Fig. 3a integration pipeline
//! floe clustering [--posts N]              Fig. 3b stream clustering (XLA)
//! floe update-demo                         in-place dynamic task update
//! floe kernels                             list loaded AOT kernels
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use floe::apps::{clustering, smartgrid};
use floe::coordinator::{Coordinator, CoordinatorServer, RuntimeOptions};
use floe::graph::DataflowGraph;
use floe::manager::{ResourceManager, SimulatedCloud};
use floe::message::{Landmark, Message};
use floe::pellet::PelletRegistry;
use floe::runtime::{default_artifact_dir, XlaRuntime};
use floe::sim::{
    compare_strategies, simulate, SimConfig, StrategyKind, WorkloadProfile,
};

const HELP: &str = "floe — continuous dataflow framework (paper reproduction)

USAGE:
  floe run <graph.xml> [--serve PORT]
  floe simulate [--profile periodic|spikes|random] [--strategy static|dynamic|hybrid|all]
                [--duration SECS] [--rate MSG_S] [--out DIR]
  floe pipeline [--events N]
  floe clustering [--posts N]
  floe update-demo
  floe kernels";

fn main() {
    floe::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("pipeline") => cmd_pipeline(&args[1..]),
        Some("clustering") => cmd_clustering(&args[1..]),
        Some("update-demo") => cmd_update_demo(),
        Some("kernels") => cmd_kernels(),
        _ => {
            eprintln!("{HELP}");
            2
        }
    };
    std::process::exit(code);
}

/// `--key value` flag lookup.
fn flag<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn coordinator() -> Coordinator {
    let cloud = SimulatedCloud::tsangpo();
    let manager = ResourceManager::new(cloud);
    Coordinator::new(manager, PelletRegistry::with_builtins())
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(path) = args.first().filter(|a| !a.starts_with("--")) else {
        floe::log_error!("run: missing graph.xml path");
        return 2;
    };
    let xml = match std::fs::read_to_string(path) {
        Ok(x) => x,
        Err(e) => {
            floe::log_error!("run: cannot read {path}: {e}");
            return 1;
        }
    };
    let graph = match DataflowGraph::from_xml(&xml) {
        Ok(g) => g,
        Err(e) => {
            floe::log_error!("run: {e}");
            return 1;
        }
    };
    let coord = coordinator();
    let run = match coord.launch(graph, RuntimeOptions::new()) {
        Ok(r) => Arc::new(r),
        Err(e) => {
            floe::log_error!("run: launch failed: {e}");
            return 1;
        }
    };
    println!(
        "launched '{}' with pellets {:?}",
        run.graph().name,
        run.pellet_ids()
    );
    if let Some(port) = flag(args, "--serve").and_then(|p| p.parse().ok()) {
        let server = CoordinatorServer::start(Arc::clone(&run), port)
            .expect("serve");
        println!("coordinator REST endpoint at http://{}", server.addr());
        println!("Ctrl-C to stop.");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    0
}

fn cmd_simulate(args: &[String]) -> i32 {
    let rate: f64 =
        flag(args, "--rate").and_then(|r| r.parse().ok()).unwrap_or(100.0);
    let profile = match flag(args, "--profile").unwrap_or("periodic") {
        "periodic" => WorkloadProfile::periodic_default(rate),
        "spikes" => WorkloadProfile::spikes_default(rate),
        "random" => WorkloadProfile::random_default(rate * 0.6),
        other => {
            floe::log_error!("simulate: unknown profile '{other}'");
            return 2;
        }
    };
    let duration: f64 = flag(args, "--duration")
        .and_then(|d| d.parse().ok())
        .unwrap_or(1800.0);
    let cfg = SimConfig { duration, ..SimConfig::default() };
    let strategy = flag(args, "--strategy").unwrap_or("all");
    let out_dir = flag(args, "--out");

    println!(
        "profile={} duration={duration}s threshold=burst+ε",
        profile.name()
    );
    println!(
        "{:<10} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "strategy", "core-secs", "peak", "mean-drain", "violations", "peak-q"
    );
    let results = if strategy == "all" {
        let (results, ratios) = compare_strategies(profile, &cfg);
        println!(
            "resource ratio static:dynamic:hybrid = {:.2}:{:.2}:{:.2}",
            ratios[0], ratios[1], ratios[2]
        );
        results
    } else {
        let kind = match strategy {
            "static" => StrategyKind::Static,
            "dynamic" => StrategyKind::Dynamic,
            "hybrid" => StrategyKind::Hybrid,
            other => {
                floe::log_error!("simulate: unknown strategy '{other}'");
                return 2;
            }
        };
        vec![simulate(profile, kind, &cfg)]
    };
    for r in &results {
        println!(
            "{:<10} {:>12.0} {:>10} {:>12.1} {:>12} {:>10.0}",
            r.strategy,
            r.core_seconds,
            r.peak_cores,
            r.mean_drain(),
            r.latency_violations,
            r.peak_queue
        );
        if let Some(dir) = out_dir {
            std::fs::create_dir_all(dir).expect("mkdir out");
            let path =
                format!("{dir}/fig4_{}_{}.csv", r.profile, r.strategy);
            r.to_csv().save(&path).expect("write csv");
            println!("  wrote {path}");
        }
    }
    0
}

fn cmd_pipeline(args: &[String]) -> i32 {
    let events: usize =
        flag(args, "--events").and_then(|n| n.parse().ok()).unwrap_or(2000);
    let store = Arc::new(smartgrid::TripleStore::new());
    let coord = coordinator();
    smartgrid::register(coord.registry(), Arc::clone(&store));
    let graph = smartgrid::integration_graph().expect("graph");
    let run = coord.launch(graph, RuntimeOptions::new()).expect("launch");

    let mut gen = smartgrid::FeedGen::new(42, 24);
    let start = Instant::now();
    for i in 0..events {
        let msg = match i % 10 {
            0..=5 => Message::text(gen.meter_event()),
            6 | 7 => Message::text(gen.sensor_event()),
            8 => Message::text(gen.noaa_xml()),
            _ => Message::text(gen.csv_archive(20)),
        };
        run.inject("parse", "in", msg).expect("inject");
    }
    let ok = run.drain(Duration::from_secs(60));
    let secs = start.elapsed().as_secs_f64();
    let ingested = run
        .flake("progress")
        .unwrap()
        .state()
        .get("ingested")
        .and_then(|j| j.as_f64())
        .unwrap_or(0.0);
    println!(
        "pipeline: {events} source messages -> {ingested} triples ingested \
         in {secs:.2}s ({:.0} msg/s), store={} triples, drained={ok}",
        ingested / secs,
        store.len()
    );
    run.stop();
    0
}

fn cmd_clustering(args: &[String]) -> i32 {
    let posts: usize =
        flag(args, "--posts").and_then(|n| n.parse().ok()).unwrap_or(1024);
    let rt = Arc::new(
        XlaRuntime::load(default_artifact_dir())
            .expect("run `make artifacts` first"),
    );
    let params =
        clustering::ClusterParams::from_manifest(&rt.manifest).expect("params");
    let model = clustering::ClusterModel::new_random(params, 7);
    let coord = coordinator();
    clustering::register(coord.registry(), Arc::clone(&rt), Arc::clone(&model));
    let graph = clustering::clustering_graph(params.batch, 2, 3).expect("graph");
    let run = coord.launch(graph, RuntimeOptions::new()).expect("launch");

    let mut gen = clustering::PostGen::new(1);
    let start = Instant::now();
    for _ in 0..posts {
        let (_topic, text) = gen.post();
        run.inject("clean", "in", Message::text(text)).expect("inject");
    }
    run.inject(
        "clean",
        "in",
        Message::landmark(Landmark::WindowEnd("flush".into())),
    )
    .expect("flush");
    let ok = run.drain(Duration::from_secs(120));
    let secs = start.elapsed().as_secs_f64();
    let assigned = run
        .flake("aggregate")
        .unwrap()
        .state()
        .get("posts")
        .and_then(|j| j.as_f64())
        .unwrap_or(0.0);
    println!(
        "clustering: {posts} posts, {assigned} assigned in {secs:.2}s \
         ({:.0} posts/s), model updates={}, drained={ok}",
        assigned / secs,
        model.update_count()
    );
    run.stop();
    0
}

fn cmd_update_demo() -> i32 {
    let coord = coordinator();
    let mut g = floe::graph::GraphBuilder::new("update-demo");
    g.pellet("work", "floe.builtin.Uppercase")
        .in_port("in")
        .out_port("out", floe::graph::SplitMode::RoundRobin);
    g.pellet("count", "floe.builtin.CountSink").in_port("in").stateful();
    g.edge("work", "out", "count", "in");
    let run = coord.launch(g.build().unwrap(), RuntimeOptions::new())
        .expect("launch");

    for i in 0..100 {
        run.inject("work", "in", Message::text(format!("pre-{i}")))
            .unwrap();
    }
    let v = run
        .update_pellet("work", Some("floe.builtin.Identity"), true, true)
        .expect("update");
    for i in 0..100 {
        run.inject("work", "in", Message::text(format!("post-{i}")))
            .unwrap();
    }
    run.drain(Duration::from_secs(10));
    let counted = run
        .flake("count")
        .unwrap()
        .state()
        .get("count")
        .and_then(|j| j.as_f64())
        .unwrap_or(0.0);
    println!(
        "update-demo: swapped Uppercase -> Identity in place (version {v}); \
         200 injected, {counted} delivered (plus update landmark), 0 lost"
    );
    run.stop();
    0
}

fn cmd_kernels() -> i32 {
    match XlaRuntime::load(default_artifact_dir()) {
        Ok(rt) => {
            println!("platform: {}", rt.platform_name());
            for name in rt.kernel_names() {
                let spec = rt.spec(name).unwrap();
                let shapes: Vec<String> = spec
                    .inputs
                    .iter()
                    .map(|t| format!("{:?}/{}", t.shape, t.dtype))
                    .collect();
                println!("  {name}({})", shapes.join(", "));
            }
            0
        }
        Err(e) => {
            floe::log_error!("kernels: {e} (run `make artifacts`)");
            1
        }
    }
}
