//! The global metrics registry: named atomic counters, gauges, and
//! log-bucketed latency histograms, rendered as Prometheus text
//! exposition (v0.0.4) by the coordinator's `GET /metrics` endpoint.
//!
//! Design constraints (ISSUE 7 tentpole):
//!
//! * **Zero allocation on the record path.**  Every instrument is a
//!   handful of `AtomicU64`s behind an `Arc`; callers resolve the
//!   `Arc` once (at spawn / first use) and record with relaxed atomic
//!   ops from then on.  The registry's own maps are touched only at
//!   registration and render time.
//! * **Histograms are log-bucketed**: 64 buckets spaced by powers of
//!   √2 (two buckets per power of two), covering 1 ns to ~4.3 s.
//!   p50/p90/p99 are read as the upper bound of the bucket holding
//!   the requested rank, so a reported quantile is never below the
//!   true value and at most one √2 step above it.
//! * **Series naming** follows `floe_<layer>_<name>` with at most one
//!   label pair (e.g. `{pellet="sink"}`, `{phase="cutover"}`,
//!   `{kind="relocate"}`); counters end in `_total`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Number of histogram buckets: 2 per power of two ⇒ √2 spacing.
pub const BUCKETS: usize = 64;

// ---------------------------------------------------------------------------
// Instruments
// ---------------------------------------------------------------------------

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (queue depths, liveness flags).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Bucket index for a recorded value: two buckets per power of two
/// (√2 spacing), clamped to [`BUCKETS`].  0 lands in bucket 0.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let top = 63 - v.leading_zeros() as usize;
    let half = (1u64 << top) >> 1;
    let idx = 2 * top + usize::from(v >= (1u64 << top) + half);
    idx.min(BUCKETS - 1)
}

/// Exclusive upper bound of a bucket — what quantile reads report.
/// Even bucket `2t` covers `[2^t, 1.5·2^t)`, odd bucket `2t+1` covers
/// `[1.5·2^t, 2^(t+1))`.
pub fn bucket_upper(idx: usize) -> u64 {
    let t = (idx >> 1) as u32;
    if idx & 1 == 0 {
        (3u64 << t) >> 1
    } else {
        1u64 << (t + 1)
    }
}

/// Lock-free latency histogram: one `AtomicU64` per bucket plus
/// count/sum/max, all relaxed — recording is a few uncontended
/// fetch-adds, no allocation, no lock.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation (nanoseconds, batch size, …).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Consistent-enough point-in-time copy (buckets are read one by
    /// one; concurrent records may straddle the walk, which only ever
    /// under-reports the newest observations).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
        }
    }

    /// Upper-bound estimate of quantile `q` in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }

    pub fn summary(&self, name: &str, label: Label) -> HistogramSummary {
        let snap = self.snapshot();
        HistogramSummary {
            name: name.to_string(),
            label,
            count: snap.count,
            sum: snap.sum,
            p50: snap.quantile(0.50),
            p90: snap.quantile(0.90),
            p99: snap.quantile(0.99),
            max: snap.max,
        }
    }
}

/// Owned, mergeable copy of a histogram's state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    /// Bucket-wise merge; associative and commutative.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Upper-bound estimate of quantile `q` in [0, 1]: the upper edge
    /// of the first bucket whose cumulative count reaches the rank.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil()
            as u64)
            .max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return if i == 0 { 0 } else { bucket_upper(i) };
            }
        }
        self.max
    }
}

/// Rendered quantile digest of one histogram series (folded into
/// [`crate::coordinator::DataflowStats`]).
#[derive(Debug, Clone)]
pub struct HistogramSummary {
    pub name: String,
    pub label: Label,
    pub count: u64,
    pub sum: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub max: u64,
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// At most one label pair per series, e.g. `("pellet", "sink")`.
pub type Label = Option<(String, String)>;

type SeriesKey = (String, Label);

#[derive(Default)]
struct Series<T> {
    map: RwLock<BTreeMap<SeriesKey, Arc<T>>>,
}

impl<T: Default> Series<T> {
    fn get_or_create(&self, name: &str, label: Label) -> Arc<T> {
        {
            let map = self.map.read().expect("series poisoned");
            if let Some(v) = map.get(&(name.to_string(), label.clone()))
            {
                return Arc::clone(v);
            }
        }
        let mut map = self.map.write().expect("series poisoned");
        Arc::clone(
            map.entry((name.to_string(), label))
                .or_insert_with(|| Arc::new(T::default())),
        )
    }

    fn snapshot(&self) -> Vec<(SeriesKey, Arc<T>)> {
        self.map
            .read()
            .expect("series poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }
}

/// The process-wide instrument store.  Instruments are registered
/// (`*_for` with a label, plain forms without) with first-wins help
/// text; repeated registration returns the existing series, so a
/// relocated flake re-attaches to its metrics instead of forking them.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Series<Counter>,
    gauges: Series<Gauge>,
    histograms: Series<Histogram>,
    /// name → help text, first registration wins.
    help: RwLock<BTreeMap<String, &'static str>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn note_help(&self, name: &str, help: &'static str) {
        let mut map = self.help.write().expect("help poisoned");
        map.entry(name.to_string()).or_insert(help);
    }

    pub fn counter(
        &self,
        name: &str,
        help: &'static str,
    ) -> Arc<Counter> {
        self.note_help(name, help);
        self.counters.get_or_create(name, None)
    }

    pub fn counter_for(
        &self,
        name: &str,
        key: &str,
        value: &str,
        help: &'static str,
    ) -> Arc<Counter> {
        self.note_help(name, help);
        self.counters
            .get_or_create(name, Some((key.to_string(), value.to_string())))
    }

    pub fn gauge(&self, name: &str, help: &'static str) -> Arc<Gauge> {
        self.note_help(name, help);
        self.gauges.get_or_create(name, None)
    }

    pub fn gauge_for(
        &self,
        name: &str,
        key: &str,
        value: &str,
        help: &'static str,
    ) -> Arc<Gauge> {
        self.note_help(name, help);
        self.gauges
            .get_or_create(name, Some((key.to_string(), value.to_string())))
    }

    pub fn histogram(
        &self,
        name: &str,
        help: &'static str,
    ) -> Arc<Histogram> {
        self.note_help(name, help);
        self.histograms.get_or_create(name, None)
    }

    pub fn histogram_for(
        &self,
        name: &str,
        key: &str,
        value: &str,
        help: &'static str,
    ) -> Arc<Histogram> {
        self.note_help(name, help);
        self.histograms
            .get_or_create(name, Some((key.to_string(), value.to_string())))
    }

    /// Quantile digests of every histogram series, for `stats_json`.
    pub fn histogram_summaries(&self) -> Vec<HistogramSummary> {
        self.histograms
            .snapshot()
            .into_iter()
            .map(|((name, label), h)| h.summary(&name, label))
            .collect()
    }

    /// Render the whole registry as Prometheus text exposition
    /// (v0.0.4).  Counters and gauges emit one sample per series;
    /// histograms are exposed as summaries (p50/p90/p99 quantile
    /// samples plus `_sum`/`_count`) — 5 lines instead of 64 buckets.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        render_family(
            &mut out,
            "counter",
            &self.counters.snapshot(),
            &self.help,
            |out, name, label, c| {
                let _ = writeln!(
                    out,
                    "{name}{} {}",
                    fmt_label(label, &[]),
                    c.get()
                );
            },
        );
        render_family(
            &mut out,
            "gauge",
            &self.gauges.snapshot(),
            &self.help,
            |out, name, label, g| {
                let _ = writeln!(
                    out,
                    "{name}{} {}",
                    fmt_label(label, &[]),
                    g.get()
                );
            },
        );
        render_family(
            &mut out,
            "summary",
            &self.histograms.snapshot(),
            &self.help,
            |out, name, label, h| {
                let snap = h.snapshot();
                for (q, qs) in
                    [(0.50, "0.5"), (0.90, "0.9"), (0.99, "0.99")]
                {
                    let _ = writeln!(
                        out,
                        "{name}{} {}",
                        fmt_label(label, &[("quantile", qs)]),
                        snap.quantile(q)
                    );
                }
                let plain = fmt_label(label, &[]);
                let _ =
                    writeln!(out, "{name}_sum{plain} {}", snap.sum);
                let _ =
                    writeln!(out, "{name}_count{plain} {}", snap.count);
            },
        );
        out
    }
}

/// Emit `# HELP` / `# TYPE` once per family followed by its series
/// (the snapshot is BTreeMap-ordered, so same-name series are
/// contiguous and the output is deterministic).
fn render_family<T>(
    out: &mut String,
    kind: &str,
    series: &[(SeriesKey, Arc<T>)],
    help: &RwLock<BTreeMap<String, &'static str>>,
    emit: impl Fn(&mut String, &str, &Label, &T),
) {
    let help = help.read().expect("help poisoned");
    let mut last_name: Option<&str> = None;
    for ((name, label), v) in series {
        if last_name != Some(name.as_str()) {
            let h = help.get(name).copied().unwrap_or("(no help)");
            let _ = writeln!(out, "# HELP {name} {h}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            last_name = Some(name.as_str());
        }
        emit(out, name, label, v);
    }
}

/// Format a label set: the series' own label plus any extra pairs
/// (used for summary quantiles).  Empty set renders as nothing.
fn fmt_label(label: &Label, extra: &[(&str, &str)]) -> String {
    let mut pairs: Vec<(String, String)> = Vec::new();
    if let Some((k, v)) = label {
        pairs.push((k.clone(), escape_label(v)));
    }
    for (k, v) in extra {
        pairs.push((k.to_string(), escape_label(v)));
    }
    if pairs.is_empty() {
        return String::new();
    }
    let body = pairs
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect::<Vec<_>>()
        .join(",");
    format!("{{{body}}}")
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_upper_bracket_values() {
        assert_eq!(bucket_index(0), 0);
        for v in [1u64, 2, 3, 7, 100, 1_000, 1 << 20, (1 << 30) + 17] {
            let idx = bucket_index(v);
            assert!(idx < BUCKETS);
            assert!(bucket_upper(idx) > v, "v={v} idx={idx}");
            // Upper bound within one √2 step: never more than 2×.
            assert!(bucket_upper(idx) <= 2 * v, "v={v} idx={idx}");
        }
    }

    #[test]
    fn histogram_quantiles_bound_recorded_values() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        assert_eq!(h.max(), 1000);
        let p50 = h.quantile(0.5);
        assert!((500..=1024).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((990..=2048).contains(&p99), "p99={p99}");
    }

    #[test]
    fn registry_renders_prometheus_text() {
        let reg = MetricsRegistry::new();
        reg.counter("floe_test_events_total", "test counter").add(3);
        reg.gauge_for("floe_test_depth", "pellet", "up", "test gauge")
            .set(7);
        reg.histogram_for(
            "floe_test_nanos",
            "pellet",
            "up",
            "test histogram",
        )
        .record(100);
        let text = reg.render();
        assert!(text.contains("# TYPE floe_test_events_total counter"));
        assert!(text.contains("floe_test_events_total 3"));
        assert!(text.contains("floe_test_depth{pellet=\"up\"} 7"));
        assert!(text.contains("# TYPE floe_test_nanos summary"));
        assert!(text
            .contains("floe_test_nanos{pellet=\"up\",quantile=\"0.5\"}"));
        assert!(text.contains("floe_test_nanos_count{pellet=\"up\"} 1"));
    }

    #[test]
    fn get_or_create_returns_same_series() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("floe_test_x_total", "x");
        let b = reg.counter("floe_test_x_total", "x");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
