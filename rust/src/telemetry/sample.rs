//! End-to-end latency sampling configuration.
//!
//! When telemetry is enabled via
//! [`RuntimeOptions::telemetry`](crate::coordinator::RuntimeOptions),
//! flakes propagate the *oldest* input ingest timestamp (the
//! `created_us` field already carried by the wire format — no layout
//! change) into the messages they emit, and sink flakes (no output
//! ports) record the age of 1-in-N arriving batches into the
//! `floe_e2e_latency_nanos{pellet=…}` histogram.  Telemetry off (the
//! default) short-circuits to a single relaxed atomic load per batch.

use std::sync::atomic::{AtomicU64, Ordering};

/// Telemetry knobs handed to
/// [`RuntimeOptions::telemetry`](crate::coordinator::RuntimeOptions::telemetry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Sample 1-in-N sink batches for e2e latency (min 1 = every
    /// batch).  Default 128: negligible cost at firehose rates while
    /// still filling latency histograms within seconds.
    pub sample_every: u64,
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig { sample_every: 128 }
    }
}

impl TelemetryConfig {
    pub fn new() -> TelemetryConfig {
        TelemetryConfig::default()
    }

    /// Override the 1-in-N sampling rate.
    pub fn sample_every(mut self, n: u64) -> TelemetryConfig {
        self.sample_every = n.max(1);
        self
    }
}

/// Lock-free 1-in-N sampler: a shared counter, `tick()` is one
/// relaxed fetch-add.
#[derive(Debug)]
pub struct Sampler {
    every: u64,
    n: AtomicU64,
}

impl Sampler {
    pub fn new(every: u64) -> Sampler {
        Sampler { every: every.max(1), n: AtomicU64::new(0) }
    }

    /// True on the 1st, N+1th, 2N+1th … call.
    pub fn tick(&self) -> bool {
        self.n.fetch_add(1, Ordering::Relaxed) % self.every == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_fires_one_in_n() {
        let s = Sampler::new(4);
        let fired: Vec<bool> = (0..8).map(|_| s.tick()).collect();
        assert_eq!(
            fired,
            [true, false, false, false, true, false, false, false]
        );
        let every_time = Sampler::new(1);
        assert!(every_time.tick() && every_time.tick());
    }

    #[test]
    fn config_builder_clamps_zero() {
        let cfg = TelemetryConfig::new().sample_every(0);
        assert_eq!(cfg.sample_every, 1);
        assert_eq!(TelemetryConfig::default().sample_every, 128);
    }
}
