//! Unified telemetry plane (ISSUE 7): a global lock-free
//! [`MetricsRegistry`], a bounded control-action [`TraceLog`], and
//! opt-in e2e latency sampling.
//!
//! Layering:
//!
//! * **Hot data paths** (ring park, TCP framing, dispatcher batches)
//!   record only when [`enabled`] — one relaxed `AtomicBool` load when
//!   off, so an un-instrumented launch pays nothing measurable (the
//!   `telemetry_overhead` section of `bench_channels` tracks this).
//! * **Control-plane events** (recompose phases, elasticity
//!   decisions, lease expiries, repairs, rebinds) are rare and record
//!   unconditionally, so `GET /metrics` and `GET /trace` are useful
//!   even on launches that never opted into sampling.
//!
//! Enable the hot paths per launch with
//! [`RuntimeOptions::telemetry`](crate::coordinator::RuntimeOptions::telemetry);
//! the registry and trace log themselves are process-global, so
//! instruments survive flake relocation and repair.

pub mod registry;
pub mod sample;
pub mod trace;

pub use registry::{
    bucket_index, bucket_upper, Counter, Gauge, Histogram,
    HistogramSnapshot, HistogramSummary, MetricsRegistry, BUCKETS,
};
pub use sample::{Sampler, TelemetryConfig};
pub use trace::{SpanGuard, SpanPhase, TraceEvent, TraceLog, TRACE_CAP};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(128);

/// Whether hot-path instruments record.  Off by default; one relaxed
/// load, inlined into every gated record site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip hot-path recording (benches use this to compare on/off on
/// the same workload).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply a launch's [`TelemetryConfig`]: sets the e2e sampling rate
/// and turns hot-path recording on.  Process-global (instruments are
/// shared), so the last launch's rate wins.
pub fn configure(cfg: TelemetryConfig) {
    SAMPLE_EVERY.store(cfg.sample_every.max(1), Ordering::Relaxed);
    set_enabled(true);
}

/// Current 1-in-N e2e sampling rate.
pub fn sample_every() -> u64 {
    SAMPLE_EVERY.load(Ordering::Relaxed)
}

/// The process-wide metrics registry.
pub fn metrics() -> &'static MetricsRegistry {
    static REG: OnceLock<MetricsRegistry> = OnceLock::new();
    REG.get_or_init(MetricsRegistry::new)
}

/// The process-wide control-action trace log.
pub fn tracelog() -> &'static TraceLog {
    static LOG: OnceLock<TraceLog> = OnceLock::new();
    LOG.get_or_init(TraceLog::default)
}

macro_rules! static_counter {
    ($(#[$doc:meta])* $fn_name:ident, $name:expr, $help:expr) => {
        $(#[$doc])*
        pub fn $fn_name() -> &'static Arc<Counter> {
            static I: OnceLock<Arc<Counter>> = OnceLock::new();
            I.get_or_init(|| metrics().counter($name, $help))
        }
    };
}

macro_rules! static_gauge {
    ($(#[$doc:meta])* $fn_name:ident, $name:expr, $help:expr) => {
        $(#[$doc])*
        pub fn $fn_name() -> &'static Arc<Gauge> {
            static I: OnceLock<Arc<Gauge>> = OnceLock::new();
            I.get_or_init(|| metrics().gauge($name, $help))
        }
    };
}

macro_rules! static_histogram {
    ($(#[$doc:meta])* $fn_name:ident, $name:expr, $help:expr) => {
        $(#[$doc])*
        pub fn $fn_name() -> &'static Arc<Histogram> {
            static I: OnceLock<Arc<Histogram>> = OnceLock::new();
            I.get_or_init(|| metrics().histogram($name, $help))
        }
    };
}

// -- channel family ---------------------------------------------------------

static_histogram!(
    /// Nanoseconds producers spent parked on a full ring shard.
    hist_ring_push_wait,
    "floe_channel_ring_push_wait_nanos",
    "Nanoseconds producers spent parked on a full ring shard"
);
static_histogram!(
    /// Nanoseconds consumers spent parked on an empty ring shard.
    hist_ring_pop_wait,
    "floe_channel_ring_pop_wait_nanos",
    "Nanoseconds consumers spent parked on an empty ring shard"
);
static_counter!(
    ctr_tcp_tx_bytes,
    "floe_channel_tcp_tx_bytes_total",
    "Bytes written to TCP data channels"
);
static_counter!(
    ctr_tcp_tx_frames,
    "floe_channel_tcp_tx_frames_total",
    "Message frames written to TCP data channels"
);
static_counter!(
    ctr_tcp_rx_bytes,
    "floe_channel_tcp_rx_bytes_total",
    "Bytes read from TCP data channels"
);
static_counter!(
    ctr_tcp_rx_frames,
    "floe_channel_tcp_rx_frames_total",
    "Message frames decoded from TCP data channels"
);
static_counter!(
    ctr_tcp_reconnects,
    "floe_channel_tcp_reconnects_total",
    "TCP sender reconnect attempts after a broken stream"
);
static_counter!(
    ctr_tcp_rebinds,
    "floe_channel_tcp_rebinds_total",
    "TCP sender rebinds to a republished endpoint"
);
static_counter!(
    /// Frames whose checksum trailer failed verification.
    ctr_tcp_corrupt_frames,
    "floe_channel_tcp_corrupt_frames_total",
    "Frames dropped after a wire-checksum mismatch"
);
static_counter!(
    /// Data connections closed by the read-side idle deadline.
    ctr_tcp_idle_closes,
    "floe_channel_tcp_idle_closes_total",
    "Data connections closed by the read-side idle deadline"
);
static_gauge!(
    /// Framed batch buffers sitting in (or in flight from) TCP
    /// sender egress queues right now, process-wide.
    gauge_tcp_egress_queue,
    "floe_channel_tcp_egress_queue_depth",
    "Framed batch buffers queued in TCP sender egress pipelines"
);
static_histogram!(
    /// Bytes handed to the kernel per egress flush syscall — shows
    /// how well the pipeline coalesces queued batches under load.
    hist_tcp_egress_flush,
    "floe_channel_tcp_egress_flush_bytes",
    "Bytes written per TCP egress flush syscall"
);
static_histogram!(
    /// Nanoseconds an egress connection spent unwritable (kernel
    /// buffer full) before progress resumed or the stall bound fired.
    hist_tcp_egress_stall,
    "floe_channel_tcp_egress_stall_nanos",
    "Nanoseconds TCP egress spent blocked on writability"
);
static_counter!(
    /// Egress flushes that coalesced more than one queued batch
    /// buffer into a single vectored write.
    ctr_tcp_egress_coalesced,
    "floe_channel_tcp_egress_coalesced_flushes_total",
    "TCP egress flushes that coalesced multiple queued batches"
);

// -- net I/O core family ----------------------------------------------------

static_gauge!(
    /// Connections registered with the event-driven I/O core.
    gauge_net_registered,
    "floe_net_connections_registered",
    "Connections registered with the event-driven I/O core"
);
static_gauge!(
    /// Connections being served by a worker right now.
    gauge_net_active,
    "floe_net_connections_active",
    "Connections currently being served by an I/O worker"
);
static_gauge!(
    /// Fixed I/O worker-pool size.
    gauge_net_workers,
    "floe_net_workers",
    "Fixed worker-pool size of the event-driven I/O core"
);

// -- recompose family -------------------------------------------------------

static_counter!(
    ctr_recompose,
    "floe_recompose_executions_total",
    "Completed live recompositions"
);

/// Per-phase recomposition duration histogram
/// (`{phase="quiesce"|"cutover"|"resume"|"downtime"}`).
pub fn hist_recompose_phase(phase: &str) -> Arc<Histogram> {
    metrics().histogram_for(
        "floe_recompose_phase_nanos",
        "phase",
        phase,
        "Nanoseconds spent per live-recomposition phase",
    )
}

// -- elasticity family ------------------------------------------------------

/// Elasticity decision counter by kind
/// (`{kind="hold"|"regrant"|"relocate"|"degraded"|"consolidate"}`).
pub fn ctr_elasticity_decision(kind: &str) -> Arc<Counter> {
    metrics().counter_for(
        "floe_elasticity_decisions_total",
        "kind",
        kind,
        "Elasticity policy decisions by kind",
    )
}

static_histogram!(
    /// Saturation-onset to relocation-execution latency.
    hist_elasticity_react,
    "floe_elasticity_time_to_react_nanos",
    "Nanoseconds from saturation onset to relocation execution"
);

// -- failover family --------------------------------------------------------

static_counter!(
    ctr_lease_expiries,
    "floe_failover_lease_expiries_total",
    "Container leases declared expired by the failure detector"
);
static_counter!(
    ctr_repairs,
    "floe_failover_repairs_total",
    "Dead containers successfully repaired"
);
static_counter!(
    ctr_checkpoints,
    "floe_failover_checkpoints_total",
    "Flake checkpoints captured"
);
static_counter!(
    ctr_checkpoint_messages,
    "floe_failover_checkpoint_messages_total",
    "In-flight messages captured into checkpoints"
);
static_counter!(
    ctr_replayed,
    "floe_failover_replayed_total",
    "Checkpointed messages replayed during repair"
);
static_histogram!(
    /// Lease-expiry detection to repaired-and-healed latency.
    hist_failover_heal,
    "floe_failover_heal_nanos",
    "Nanoseconds from failure detection to completed repair"
);
static_counter!(
    /// Endpoint-deadline expiries surfaced to the failure detector.
    ctr_endpoint_stalls,
    "floe_failover_endpoint_stalls_total",
    "Endpoint send deadlines expired and surfaced as partition \
     suspicions"
);

// -- chaos family (deterministic fault injection) ---------------------------

static_counter!(
    /// Fault plans armed over this process's lifetime.
    ctr_chaos_arms,
    "floe_chaos_plans_armed_total",
    "Fault-injection plans armed"
);

/// Injected fault counter by kind (`{fault="drop"|"delay"|...}`).
pub fn ctr_chaos_injected(fault: &str) -> Arc<Counter> {
    metrics().counter_for(
        "floe_chaos_injected_faults_total",
        "fault",
        fault,
        "Faults injected by the armed chaos plan, by kind",
    )
}

// -- flake / e2e families (per-pellet, resolved at flake spawn) -------------

/// Dispatcher batch-size histogram for one pellet.
pub fn hist_flake_batch(pellet: &str) -> Arc<Histogram> {
    metrics().histogram_for(
        "floe_flake_batch_size",
        "pellet",
        pellet,
        "Messages per dispatched batch",
    )
}

/// Pellet compute service-latency histogram.
pub fn hist_flake_service(pellet: &str) -> Arc<Histogram> {
    metrics().histogram_for(
        "floe_flake_service_nanos",
        "pellet",
        pellet,
        "Nanoseconds per pellet compute call",
    )
}

/// Duplicate messages dropped by the dedup filter for one pellet.
pub fn ctr_flake_dedup_drops(pellet: &str) -> Arc<Counter> {
    metrics().counter_for(
        "floe_flake_dedup_drops_total",
        "pellet",
        pellet,
        "Duplicate messages dropped by the dedup filter",
    )
}

/// Sampled end-to-end (ingest → sink) latency for one sink pellet.
pub fn hist_e2e_latency(pellet: &str) -> Arc<Histogram> {
    metrics().histogram_for(
        "floe_e2e_latency_nanos",
        "pellet",
        pellet,
        "Sampled end-to-end latency from ingest to sink",
    )
}

/// Scrape-time queue-depth gauge for one pellet.
pub fn gauge_queue_depth(pellet: &str) -> Arc<Gauge> {
    metrics().gauge_for(
        "floe_channel_queue_depth",
        "pellet",
        pellet,
        "Buffered messages on a pellet's input shards at scrape time",
    )
}

/// Eagerly register one instrument from each family so a fresh
/// `/metrics` scrape always exposes the channel, recompose,
/// elasticity, and failover families even before traffic has touched
/// them.
pub fn touch() {
    hist_ring_push_wait();
    hist_ring_pop_wait();
    ctr_tcp_tx_bytes();
    ctr_tcp_tx_frames();
    ctr_tcp_rx_bytes();
    ctr_tcp_rx_frames();
    ctr_tcp_reconnects();
    ctr_tcp_rebinds();
    ctr_tcp_corrupt_frames();
    ctr_tcp_idle_closes();
    gauge_tcp_egress_queue();
    hist_tcp_egress_flush();
    hist_tcp_egress_stall();
    ctr_tcp_egress_coalesced();
    gauge_net_registered();
    gauge_net_active();
    gauge_net_workers();
    ctr_recompose();
    hist_recompose_phase("downtime");
    ctr_elasticity_decision("hold");
    hist_elasticity_react();
    ctr_lease_expiries();
    ctr_repairs();
    ctr_checkpoints();
    ctr_checkpoint_messages();
    ctr_replayed();
    hist_failover_heal();
    ctr_endpoint_stalls();
    ctr_chaos_arms();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_registers_all_required_families() {
        touch();
        let text = metrics().render();
        for family in [
            "floe_channel_",
            "floe_net_",
            "floe_recompose_",
            "floe_elasticity_",
            "floe_failover_",
            "floe_chaos_",
        ] {
            assert!(text.contains(family), "missing family {family}");
        }
    }

    #[test]
    fn enabled_defaults_off_and_configure_turns_on() {
        // Other tests may have configured telemetry already; only
        // assert the configure -> enabled edge.
        configure(TelemetryConfig::new().sample_every(7));
        assert!(enabled());
        assert_eq!(sample_every(), 7);
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
    }
}
