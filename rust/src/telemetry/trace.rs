//! Bounded, overwrite-oldest trace log of control-plane span events.
//!
//! Every recomposition, relocation, repair, consolidation, and rebind
//! records begin/end (or instant) events with monotonic timestamps and
//! an outcome string — the audit trail served by `GET /trace?since=`.
//! Control actions are rare (human-timescale), so a mutex-guarded ring
//! is plenty; the hot data path never touches this log.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Default event capacity; oldest events are overwritten beyond it.
pub const TRACE_CAP: usize = 1024;

/// Whether an event opens a span, closes one, or stands alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanPhase {
    Begin,
    End,
    Instant,
}

impl SpanPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanPhase::Begin => "begin",
            SpanPhase::End => "end",
            SpanPhase::Instant => "instant",
        }
    }
}

/// One timeline entry.  `t_ms` is milliseconds since process start
/// (monotonic clock), so begin/end pairs subtract exactly.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub seq: u64,
    pub t_ms: f64,
    pub kind: String,
    pub phase: SpanPhase,
    pub target: String,
    pub outcome: String,
}

struct Inner {
    next_seq: u64,
    events: VecDeque<TraceEvent>,
}

/// Fixed-capacity span-event ring.  `begin`/`end` bracket an action on
/// a target (container, flake, endpoint); `instant` marks a point
/// event such as a failure detection or a TCP rebind.
pub struct TraceLog {
    cap: usize,
    inner: Mutex<Inner>,
}

impl Default for TraceLog {
    fn default() -> TraceLog {
        TraceLog::new(TRACE_CAP)
    }
}

impl TraceLog {
    pub fn new(cap: usize) -> TraceLog {
        TraceLog {
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                next_seq: 0,
                events: VecDeque::new(),
            }),
        }
    }

    fn push(
        &self,
        kind: &str,
        phase: SpanPhase,
        target: &str,
        outcome: &str,
    ) -> u64 {
        let t_ms = epoch().elapsed().as_secs_f64() * 1e3;
        let mut inner = self.inner.lock().expect("trace poisoned");
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.events.len() == self.cap {
            inner.events.pop_front();
        }
        inner.events.push_back(TraceEvent {
            seq,
            t_ms,
            kind: kind.to_string(),
            phase,
            target: target.to_string(),
            outcome: outcome.to_string(),
        });
        seq
    }

    /// Open a span; pair with [`TraceLog::end`] on the same
    /// kind/target.
    pub fn begin(&self, kind: &str, target: &str) -> u64 {
        self.push(kind, SpanPhase::Begin, target, "")
    }

    /// Close a span with an outcome (`"ok"`, `"error: …"`).
    pub fn end(&self, kind: &str, target: &str, outcome: &str) -> u64 {
        self.push(kind, SpanPhase::End, target, outcome)
    }

    /// Record a point event.
    pub fn instant(
        &self,
        kind: &str,
        target: &str,
        outcome: &str,
    ) -> u64 {
        self.push(kind, SpanPhase::Instant, target, outcome)
    }

    /// RAII span: ends with the outcome passed to
    /// [`SpanGuard::finish`], or `"aborted"` if dropped early (e.g.
    /// an `?` return unwinding out of a recomposition).
    pub fn span(&self, kind: &str, target: &str) -> SpanGuard<'_> {
        self.begin(kind, target);
        SpanGuard {
            log: self,
            kind: kind.to_string(),
            target: target.to_string(),
            finished: false,
        }
    }

    /// Sequence number the next event will get; pass to
    /// [`TraceLog::since`] to read only newer events.
    pub fn next_seq(&self) -> u64 {
        self.inner.lock().expect("trace poisoned").next_seq
    }

    /// Events with `seq >= seq` still in the ring, oldest first.
    pub fn since(&self, seq: u64) -> Vec<TraceEvent> {
        self.inner
            .lock()
            .expect("trace poisoned")
            .events
            .iter()
            .filter(|e| e.seq >= seq)
            .cloned()
            .collect()
    }

    /// Everything still in the ring, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.since(0)
    }
}

/// See [`TraceLog::span`].
pub struct SpanGuard<'a> {
    log: &'a TraceLog,
    kind: String,
    target: String,
    finished: bool,
}

impl SpanGuard<'_> {
    pub fn finish(mut self, outcome: &str) {
        self.log.end(&self.kind, &self.target, outcome);
        self.finished = true;
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.log.end(&self.kind, &self.target, "aborted");
        }
    }
}

/// Process-start anchor for `t_ms`; shared by every trace event.
fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_pair_and_timestamps_advance() {
        let log = TraceLog::new(16);
        log.begin("repair", "c-1");
        log.end("repair", "c-1", "ok");
        let events = log.snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].phase, SpanPhase::Begin);
        assert_eq!(events[1].phase, SpanPhase::End);
        assert_eq!(events[1].outcome, "ok");
        assert!(events[0].t_ms <= events[1].t_ms);
        assert!(events[0].seq < events[1].seq);
    }

    #[test]
    fn ring_overwrites_oldest_and_since_filters() {
        let log = TraceLog::new(4);
        for i in 0..10u64 {
            log.instant("tick", &format!("t{i}"), "");
        }
        let events = log.snapshot();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].seq, 6);
        assert_eq!(log.since(8).len(), 2);
        assert_eq!(log.next_seq(), 10);
    }

    #[test]
    fn dropped_guard_records_aborted() {
        let log = TraceLog::new(8);
        {
            let _g = log.span("recompose", "v2");
        }
        let events = log.snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[1].outcome, "aborted");
        {
            let g = log.span("recompose", "v3");
            g.finish("ok");
        }
        assert_eq!(log.snapshot()[3].outcome, "ok");
    }
}
