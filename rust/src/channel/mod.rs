//! Data channels between flakes.
//!
//! §III: "Floe offers multiple transport channels, including direct socket
//! connections between flakes".  Two transports share one [`Transport`]
//! trait: in-process bounded queues (flakes co-located in a container) and
//! framed TCP sockets (flakes on different VMs).  The bounded queue is the
//! backpressure mechanism: senders block when a sink pellet falls behind.

mod queue;
mod tcp;

pub use queue::{QueueClosed, SyncQueue};
pub use tcp::{TcpReceiver, TcpSender};

use std::sync::Arc;

use crate::error::{FloeError, Result};
use crate::message::Message;

/// A one-way message transport from an output port to one sink flake's
/// input port.
pub trait Transport: Send + Sync {
    /// Deliver one message.  Blocks on backpressure.
    fn send(&self, msg: Message) -> Result<()>;

    /// Human-readable description for diagnostics.
    fn describe(&self) -> String;
}

/// In-process transport: pushes straight into the sink flake's input queue.
pub struct InProcTransport {
    pub queue: Arc<SyncQueue<Message>>,
    pub label: String,
}

impl Transport for InProcTransport {
    fn send(&self, msg: Message) -> Result<()> {
        self.queue
            .push(msg)
            .map_err(|_| FloeError::Channel(format!("{} closed", self.label)))
    }

    fn describe(&self) -> String {
        format!("inproc:{}", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_transport_delivers() {
        let q = Arc::new(SyncQueue::new(16));
        let t = InProcTransport { queue: Arc::clone(&q), label: "t".into() };
        t.send(Message::text("a")).unwrap();
        t.send(Message::text("b")).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().as_text(), Some("a"));
    }

    #[test]
    fn inproc_transport_errors_after_close() {
        let q = Arc::new(SyncQueue::new(4));
        let t = InProcTransport { queue: Arc::clone(&q), label: "t".into() };
        q.close();
        assert!(t.send(Message::empty()).is_err());
    }
}
