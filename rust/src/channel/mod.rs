//! Data channels between flakes.
//!
//! §III: "Floe offers multiple transport channels, including direct socket
//! connections between flakes".  Two transports share one [`Transport`]
//! trait: in-process bounded queues (flakes co-located in a container) and
//! framed TCP sockets (flakes on different VMs).  The bounded queue is the
//! backpressure mechanism: senders block when a sink pellet falls behind.
//!
//! # Batching, sharding and the lock-free backend
//!
//! The channel layer is the per-message floor of the whole runtime, so it
//! offers a **batched, shard-aware, lock-free fast path** on top of the
//! paper's blocking-queue contract:
//!
//! * **Batch API** — `push_batch` / `pop_batch` move N messages per
//!   claim instead of N claims.  Batching is opportunistic on the pop
//!   side (a consumer never waits for a batch to fill), so latency stays
//!   at single-message levels while synchronization traffic drops by
//!   the batch size.
//! * **Sharding** — [`ShardedQueue`] splits a flake input port into
//!   per-producer-thread sub-queues with a round-robin consumer sweep,
//!   eliminating producer convoying under fan-in.  Ordering is FIFO per
//!   producer thread; backpressure and drain-before-close semantics are
//!   preserved per shard.
//! * **Lock-free shards** — each shard is a [`RingQueue`] by default: a
//!   Vyukov-style bounded ring (atomic head/tail, power-of-two
//!   capacity) whose batch ops claim a whole run of slots with a single
//!   compare-and-swap.  The mutex [`SyncQueue`] remains available as
//!   the reference backend via [`ChannelBackend::Mutex`]
//!   (`bench_channels` reports the two head-to-head).
//! * **Batch transports** — [`Transport::send_batch`] lets the output
//!   router hand a whole emission batch to a channel: the in-process
//!   transport forwards it as one `push_batch`, the TCP transport
//!   frames into a reusable per-connection scratch buffer and writes
//!   all frames in one syscall (see [`TcpSender`]).
//!
//! How many messages ride in one batch is controlled by the `batch_size`
//! knob on [`crate::flake::FlakeConfig`] (default
//! [`crate::flake::DEFAULT_BATCH_SIZE`]); batch size, shard count and
//! the channel backend are all surfaced through
//! `RuntimeOptions`/`FlakeConfig`.
//!
//! # Location transparency
//!
//! On top of the physical transports sits the **logical endpoint
//! layer** ([`EndpointAddr`], [`EndpointTable`],
//! [`EndpointTransport`]): every flake input port has a stable
//! `floe://<flake-id>/<port>` address, and senders resolve logical →
//! physical through a versioned routing table instead of holding
//! queues or sockets directly.  A flake relocation republishes the
//! moved flake's endpoints (version bump) and every sender — local
//! edge transports, logical [`TcpSender`]s, and the table-resolving
//! [`TcpReceiver`] delivery path — re-resolves and carries on.  See
//! `endpoint.rs` for the design notes.

mod endpoint;
mod queue;
mod ring;
mod sharded;
mod tcp;

pub use endpoint::{
    EndpointAddr, EndpointTable, EndpointTransport, ENDPOINT_SCHEME,
};
pub use queue::{QueueClosed, SyncQueue};
pub use ring::RingQueue;
pub use sharded::{ShardedQueue, DEFAULT_SHARDS};
pub use tcp::{
    set_egress_queue_cap, set_rx_idle_limit, set_write_stall_timeout,
    TcpReceiver, TcpSender,
};

/// Which primitive backs each [`ShardedQueue`] shard on the data plane.
///
/// `Ring` is the default production fast path; `Mutex` is the original
/// blocking queue, kept as a reference implementation so benches can
/// report ring-vs-mutex numbers and the recompose/elasticity suites can
/// run on both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChannelBackend {
    /// Lock-free bounded MPMC ring ([`RingQueue`]).
    #[default]
    Ring,
    /// Mutex + condvar blocking queue ([`SyncQueue`]).
    Mutex,
}

use std::sync::Arc;

use crate::error::{FloeError, Result};
use crate::message::Message;

/// A one-way message transport from an output port to one sink flake's
/// input port.
pub trait Transport: Send + Sync {
    /// Deliver one message.  Blocks on backpressure.
    fn send(&self, msg: Message) -> Result<()>;

    /// Deliver a batch of messages in order.  Blocks on backpressure.
    /// The default forwards one by one; transports override it to
    /// amortize per-message costs (lock round-trips, syscalls).
    fn send_batch(&self, msgs: Vec<Message>) -> Result<()> {
        for msg in msgs {
            self.send(msg)?;
        }
        Ok(())
    }

    /// Best-effort non-blocking delivery: `Ok(true)` = delivered,
    /// `Ok(false)` = dropped because the channel is full right now,
    /// `Err` = channel closed/broken.  Used for control messages
    /// (landmarks) that must never block the sender — e.g. the
    /// recomposition engine broadcasting a cut marker into a paused
    /// sibling's full queue.  The default falls back to the blocking
    /// send (remote transports drain independently of flake pauses).
    fn try_send(&self, msg: Message) -> Result<bool> {
        self.send(msg).map(|()| true)
    }

    /// Human-readable description for diagnostics.
    fn describe(&self) -> String;
}

/// In-process transport: pushes straight into the sink flake's sharded
/// input queue.  The calling thread's shard pinning keeps each upstream
/// worker contention-free and its messages in order.
pub struct InProcTransport {
    pub queue: Arc<ShardedQueue<Message>>,
    pub label: String,
}

impl Transport for InProcTransport {
    fn send(&self, msg: Message) -> Result<()> {
        self.queue
            .push(msg)
            .map_err(|_| FloeError::Channel(format!("{} closed", self.label)))
    }

    fn send_batch(&self, msgs: Vec<Message>) -> Result<()> {
        self.queue.push_batch(msgs).map_err(|_| {
            FloeError::Channel(format!("{} closed", self.label))
        })
    }

    fn try_send(&self, msg: Message) -> Result<bool> {
        match self.queue.try_push(msg) {
            Ok(()) => Ok(true),
            Err(_) if self.queue.is_closed() => Err(FloeError::Channel(
                format!("{} closed", self.label),
            )),
            Err(_) => Ok(false),
        }
    }

    fn describe(&self) -> String {
        format!("inproc:{}", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_transport_delivers() {
        let q = Arc::new(ShardedQueue::with_default_shards(16));
        let t = InProcTransport { queue: Arc::clone(&q), label: "t".into() };
        t.send(Message::text("a")).unwrap();
        t.send(Message::text("b")).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().as_text(), Some("a"));
    }

    #[test]
    fn inproc_transport_batch_delivers_in_order() {
        let q = Arc::new(ShardedQueue::with_default_shards(64));
        let t = InProcTransport { queue: Arc::clone(&q), label: "t".into() };
        let batch: Vec<Message> =
            (0..10).map(|i| Message::text(format!("m{i}"))).collect();
        t.send_batch(batch).unwrap();
        assert_eq!(q.len(), 10);
        for i in 0..10 {
            assert_eq!(
                q.pop().unwrap().as_text(),
                Some(format!("m{i}").as_str())
            );
        }
    }

    #[test]
    fn inproc_transport_errors_after_close() {
        let q = Arc::new(ShardedQueue::with_default_shards(4));
        let t = InProcTransport { queue: Arc::clone(&q), label: "t".into() };
        q.close();
        assert!(t.send(Message::empty()).is_err());
        assert!(t.send_batch(vec![Message::empty()]).is_err());
    }
}
