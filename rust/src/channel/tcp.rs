//! TCP transport between flakes on different VMs/containers.
//!
//! Wire format per message frame:
//! `[u32 total_len][u16 port_len][port name bytes][message bytes]` with the
//! message encoded by [`Message::encode`].  A [`TcpReceiver`] listens on the
//! flake's endpoint, decodes frames and pushes them into the named input
//! port queue; a [`TcpSender`] holds one connection per (sink, port) pair.
//!
//! Both directions are batch-aware and allocation-slim:
//! [`TcpSender::send_batch`] encodes every frame into a reusable
//! per-connection scratch buffer ([`Message::encode_into`] — no
//! per-message `Vec`) and issues a single `write_all` (one syscall per
//! batch instead of one per message); the receiver reads
//! socket-buffer-sized chunks into one reusable accumulator, decodes
//! every complete frame, and delivers them per port with one
//! [`ShardedQueue::push_batch`].

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::channel::{ShardedQueue, Transport};
use crate::error::{FloeError, Result};
use crate::message::Message;

/// Hard ceiling on one frame (64 MiB) — rejects corrupt length prefixes.
const MAX_FRAME: usize = 64 << 20;

/// Receive chunk size: one read syscall can carry many small frames.
const READ_CHUNK: usize = 64 << 10;

/// Listens for framed messages and pushes them into per-port input queues.
pub struct TcpReceiver {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl TcpReceiver {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and route incoming frames into
    /// `ports` by port name.  Unknown ports are dropped with a log line.
    pub fn start(
        port: u16,
        ports: HashMap<String, Arc<ShardedQueue<Message>>>,
    ) -> Result<TcpReceiver> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let ports = Arc::new(ports);
        let join = thread::Builder::new()
            .name(format!("flake-rx-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let ports = Arc::clone(&ports);
                            let stop3 = Arc::clone(&stop2);
                            thread::spawn(move || {
                                let _ = serve_stream(stream, &ports, &stop3);
                            });
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn tcp receiver");
        Ok(TcpReceiver { addr, stop, join: Some(join) })
    }

    /// `host:port` of this receiver.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpReceiver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Per-connection read loop: accumulate raw bytes, decode every complete
/// frame, deliver frames grouped per port with one batch push each.
fn serve_stream(
    mut stream: TcpStream,
    ports: &HashMap<String, Arc<ShardedQueue<Message>>>,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut acc: Vec<u8> = Vec::with_capacity(READ_CHUNK);
    let mut chunk = vec![0u8; READ_CHUNK];
    // Reused across reads: per-port delivery groups for this chunk.
    let mut deliveries: Vec<(String, Vec<Message>)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let n = match stream.read(&mut chunk) {
            Ok(0) => {
                // Peer closed.  Bytes left in the accumulator mean the
                // peer died mid-frame — surface the data loss instead of
                // treating it as a clean shutdown.
                if acc.is_empty() {
                    return Ok(());
                }
                return Err(FloeError::Channel(format!(
                    "tcp: peer closed mid-frame ({} byte(s) undecoded)",
                    acc.len()
                )));
            }
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return Ok(()), // peer reset
        };
        acc.extend_from_slice(&chunk[..n]);

        // Decode every complete frame in the accumulator, grouping
        // consecutive messages per port so each group lands in the sink
        // queue through one push_batch.  A corrupt frame poisons the
        // connection, but everything decoded before it is still
        // delivered below.
        let mut consumed = 0usize;
        let mut frame_err: Option<FloeError> = None;
        loop {
            let avail = acc.len() - consumed;
            if avail < 4 {
                break;
            }
            let total = u32::from_le_bytes(
                acc[consumed..consumed + 4].try_into().expect("4 bytes"),
            ) as usize;
            if total < 2 || total > MAX_FRAME {
                frame_err = Some(FloeError::Channel(format!(
                    "tcp: bad frame length {total}"
                )));
                break;
            }
            if avail < 4 + total {
                break; // incomplete frame; wait for more bytes
            }
            let frame = &acc[consumed + 4..consumed + 4 + total];
            let port_len =
                u16::from_le_bytes([frame[0], frame[1]]) as usize;
            if 2 + port_len > frame.len() {
                frame_err = Some(FloeError::Channel(
                    "tcp: bad port length".into(),
                ));
                break;
            }
            let port = &frame[2..2 + port_len];
            let msg = match Message::decode(&frame[2 + port_len..]) {
                Ok(m) => m,
                Err(e) => {
                    frame_err = Some(e);
                    break;
                }
            };
            // The port name String is allocated once per run of
            // same-port frames, not once per frame.
            let same_port = matches!(
                deliveries.last(), Some((p, _)) if p.as_bytes() == port
            );
            if same_port {
                deliveries.last_mut().expect("non-empty").1.push(msg);
            } else {
                let port =
                    String::from_utf8_lossy(port).into_owned();
                deliveries.push((port, vec![msg]));
            }
            consumed += 4 + total;
        }
        if consumed > 0 {
            acc.drain(..consumed);
        }
        for (port, batch) in deliveries.drain(..) {
            match ports.get(&port) {
                Some(q) => {
                    if q.push_batch(batch).is_err() {
                        return Ok(()); // flake shut down
                    }
                }
                None => {
                    crate::log_warn!(
                        "tcp: dropping {} message(s) for unknown port \
                         {port}",
                        batch.len()
                    );
                }
            }
        }
        if let Some(e) = frame_err {
            return Err(e);
        }
    }
    Ok(())
}

/// Don't let one giant batch pin a huge scratch buffer forever.
const SCRATCH_KEEP: usize = 1 << 20;

/// Connection state behind one lock: the socket and the reusable frame
/// scratch buffer (framing and writing happen under the same critical
/// section anyway, so sharing the lock costs nothing and saves an
/// allocation per batch).
struct SenderInner {
    stream: Option<TcpStream>,
    scratch: Vec<u8>,
}

/// Sends framed messages to one sink flake's input port over TCP.
pub struct TcpSender {
    endpoint: String,
    port_name: String,
    inner: Mutex<SenderInner>,
}

impl TcpSender {
    pub fn connect(endpoint: &str, port_name: &str) -> Result<TcpSender> {
        let stream = TcpStream::connect(endpoint)?;
        stream.set_nodelay(true)?;
        Ok(TcpSender {
            endpoint: endpoint.to_string(),
            port_name: port_name.to_string(),
            inner: Mutex::new(SenderInner {
                stream: Some(stream),
                scratch: Vec::with_capacity(4096),
            }),
        })
    }

    /// Append one frame, encoding the message straight into `out`
    /// (no intermediate body buffer): the length prefix is written as a
    /// placeholder and backpatched once the encoded size is known.
    fn frame_into(port_name: &str, msg: &Message, out: &mut Vec<u8>) {
        let len_at = out.len();
        out.extend_from_slice(&[0u8; 4]); // total-length placeholder
        out.extend_from_slice(&(port_name.len() as u16).to_le_bytes());
        out.extend_from_slice(port_name.as_bytes());
        msg.encode_into(out);
        let total = (out.len() - len_at - 4) as u32;
        out[len_at..len_at + 4].copy_from_slice(&total.to_le_bytes());
    }

    /// Write the framed scratch buffer, reconnecting once on a broken
    /// pipe.
    ///
    /// Delivery is at-least-once across reconnects: if the connection
    /// breaks mid-buffer, the retry resends the whole buffer, so frames
    /// the receiver already consumed may arrive again.  With batching
    /// the duplication window is the batch, not one message — sinks that
    /// cannot tolerate duplicates should dedupe on `Message::seq`.
    fn write_frames(
        endpoint: &str,
        slot: &mut Option<TcpStream>,
        frames: &[u8],
    ) -> Result<()> {
        for attempt in 0..2 {
            if slot.is_none() {
                *slot = Some(TcpStream::connect(endpoint).map_err(|e| {
                    FloeError::Channel(format!(
                        "tcp reconnect to {endpoint}: {e}"
                    ))
                })?);
            }
            let stream = slot.as_mut().expect("just set");
            match stream.write_all(frames).and_then(|_| stream.flush()) {
                Ok(()) => return Ok(()),
                Err(e) if attempt == 0 => {
                    crate::log_debug!("tcp send failed ({e}), reconnecting");
                    *slot = None;
                }
                Err(e) => {
                    return Err(FloeError::Channel(format!(
                        "tcp send to {endpoint}: {e}"
                    )))
                }
            }
        }
        unreachable!()
    }

    /// Frame `msgs` into the per-connection scratch buffer and write
    /// them with one syscall.
    fn send_all(&self, msgs: &[Message]) -> Result<()> {
        let mut g = self.inner.lock().expect("tcp sender poisoned");
        let SenderInner { stream, scratch } = &mut *g;
        scratch.clear();
        for msg in msgs {
            Self::frame_into(&self.port_name, msg, scratch);
        }
        let result = Self::write_frames(&self.endpoint, stream, scratch);
        if scratch.capacity() > SCRATCH_KEEP {
            scratch.shrink_to(SCRATCH_KEEP);
        }
        result
    }
}

impl Transport for TcpSender {
    fn send(&self, msg: Message) -> Result<()> {
        self.send_all(std::slice::from_ref(&msg))
    }

    /// Frame the whole batch into the reusable scratch buffer and write
    /// it with a single syscall.
    fn send_batch(&self, msgs: Vec<Message>) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        self.send_all(&msgs)
    }

    fn describe(&self) -> String {
        format!("tcp:{}#{}", self.endpoint, self.port_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_pair() -> (TcpReceiver, Arc<ShardedQueue<Message>>, String) {
        let q = Arc::new(ShardedQueue::with_default_shards(4096));
        let mut ports = HashMap::new();
        ports.insert("in".to_string(), Arc::clone(&q));
        let rx = TcpReceiver::start(0, ports).unwrap();
        let ep = rx.endpoint();
        (rx, q, ep)
    }

    #[test]
    fn roundtrip_over_tcp() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("one").with_key("k")).unwrap();
        tx.send(Message::f32s(vec![1.0, 2.0, 3.0])).unwrap();
        let a = q.pop().unwrap();
        assert_eq!(a.as_text(), Some("one"));
        assert_eq!(a.key.as_deref(), Some("k"));
        let b = q.pop().unwrap();
        assert_eq!(b.as_f32s(), Some(&[1.0f32, 2.0, 3.0][..]));
        rx.shutdown();
    }

    #[test]
    fn many_messages_in_order() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        for i in 0..500 {
            tx.send(Message::text(format!("m{i}"))).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop().unwrap().as_text(), Some(&*format!("m{i}")));
        }
        rx.shutdown();
    }

    #[test]
    fn batch_send_arrives_in_order() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        for chunk in 0..10 {
            let batch: Vec<Message> = (0..100)
                .map(|i| Message::text(format!("b{}", chunk * 100 + i)))
                .collect();
            tx.send_batch(batch).unwrap();
        }
        for i in 0..1000 {
            assert_eq!(q.pop().unwrap().as_text(), Some(&*format!("b{i}")));
        }
        rx.shutdown();
    }

    #[test]
    fn unknown_port_dropped_known_delivered() {
        let (mut rx, q, ep) = start_pair();
        let bad = TcpSender::connect(&ep, "nope").unwrap();
        bad.send(Message::text("lost")).unwrap();
        let good = TcpSender::connect(&ep, "in").unwrap();
        good.send(Message::text("kept")).unwrap();
        assert_eq!(q.pop().unwrap().as_text(), Some("kept"));
        assert!(q.is_empty());
        rx.shutdown();
    }

    #[test]
    fn concurrent_senders() {
        let (mut rx, q, ep) = start_pair();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let ep = ep.clone();
                thread::spawn(move || {
                    let tx = TcpSender::connect(&ep, "in").unwrap();
                    for i in 0..100 {
                        tx.send(Message::text(format!("{t}-{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..400 {
            got.push(q.pop().unwrap().as_text().unwrap().to_string());
        }
        got.sort();
        got.dedup();
        assert_eq!(got.len(), 400);
        rx.shutdown();
    }
}
