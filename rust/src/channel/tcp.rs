//! TCP transport between flakes on different VMs/containers.
//!
//! Wire format per message frame:
//! `[u32 total_len][u16 port_len][port name bytes][message bytes]` with the
//! message encoded by [`Message::encode`].  A [`TcpReceiver`] listens on the
//! flake's endpoint, decodes frames and pushes them into the named input
//! port queue; a [`TcpSender`] holds one connection per (sink, port) pair.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::channel::{SyncQueue, Transport};
use crate::error::{FloeError, Result};
use crate::message::Message;

/// Listens for framed messages and pushes them into per-port input queues.
pub struct TcpReceiver {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl TcpReceiver {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and route incoming frames into
    /// `ports` by port name.  Unknown ports are dropped with a log line.
    pub fn start(
        port: u16,
        ports: HashMap<String, Arc<SyncQueue<Message>>>,
    ) -> Result<TcpReceiver> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let ports = Arc::new(ports);
        let join = thread::Builder::new()
            .name(format!("flake-rx-{}", addr.port()))
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let ports = Arc::clone(&ports);
                            let stop3 = Arc::clone(&stop2);
                            thread::spawn(move || {
                                let _ = serve_stream(stream, &ports, &stop3);
                            });
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn tcp receiver");
        Ok(TcpReceiver { addr, stop, join: Some(join) })
    }

    /// `host:port` of this receiver.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpReceiver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn serve_stream(
    mut stream: TcpStream,
    ports: &HashMap<String, Arc<SyncQueue<Message>>>,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut len_buf = [0u8; 4];
    while !stop.load(Ordering::SeqCst) {
        match stream.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return Ok(()), // peer closed
        }
        let total = u32::from_le_bytes(len_buf) as usize;
        if total < 2 || total > 64 << 20 {
            return Err(FloeError::Channel(format!(
                "tcp: bad frame length {total}"
            )));
        }
        let mut frame = vec![0u8; total];
        read_fully(&mut stream, &mut frame, stop)?;
        let port_len =
            u16::from_le_bytes([frame[0], frame[1]]) as usize;
        if 2 + port_len > frame.len() {
            return Err(FloeError::Channel("tcp: bad port length".into()));
        }
        let port =
            String::from_utf8_lossy(&frame[2..2 + port_len]).into_owned();
        let msg = Message::decode(&frame[2 + port_len..])?;
        match ports.get(&port) {
            Some(q) => {
                if q.push(msg).is_err() {
                    return Ok(()); // flake shut down
                }
            }
            None => {
                log::warn!("tcp: dropping message for unknown port {port}");
            }
        }
    }
    Ok(())
}

fn read_fully(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<()> {
    let mut read = 0;
    while read < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Err(FloeError::Channel("tcp: shutdown mid-frame".into()));
        }
        match stream.read(&mut buf[read..]) {
            Ok(0) => {
                return Err(FloeError::Channel(
                    "tcp: peer closed mid-frame".into(),
                ))
            }
            Ok(n) => read += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Sends framed messages to one sink flake's input port over TCP.
pub struct TcpSender {
    endpoint: String,
    port_name: String,
    stream: Mutex<Option<TcpStream>>,
}

impl TcpSender {
    pub fn connect(endpoint: &str, port_name: &str) -> Result<TcpSender> {
        let stream = TcpStream::connect(endpoint)?;
        stream.set_nodelay(true)?;
        Ok(TcpSender {
            endpoint: endpoint.to_string(),
            port_name: port_name.to_string(),
            stream: Mutex::new(Some(stream)),
        })
    }

    fn frame(&self, msg: &Message) -> Vec<u8> {
        let body = msg.encode();
        let port = self.port_name.as_bytes();
        let total = 2 + port.len() + body.len();
        let mut out = Vec::with_capacity(4 + total);
        out.extend_from_slice(&(total as u32).to_le_bytes());
        out.extend_from_slice(&(port.len() as u16).to_le_bytes());
        out.extend_from_slice(port);
        out.extend_from_slice(&body);
        out
    }
}

impl Transport for TcpSender {
    fn send(&self, msg: Message) -> Result<()> {
        let frame = self.frame(&msg);
        let mut guard = self.stream.lock().expect("tcp sender poisoned");
        // One reconnect attempt on a broken pipe.
        for attempt in 0..2 {
            if guard.is_none() {
                *guard = Some(
                    TcpStream::connect(&self.endpoint).map_err(|e| {
                        FloeError::Channel(format!(
                            "tcp reconnect to {}: {e}",
                            self.endpoint
                        ))
                    })?,
                );
            }
            let stream = guard.as_mut().expect("just set");
            match stream.write_all(&frame).and_then(|_| stream.flush()) {
                Ok(()) => return Ok(()),
                Err(e) if attempt == 0 => {
                    log::debug!("tcp send failed ({e}), reconnecting");
                    *guard = None;
                }
                Err(e) => {
                    return Err(FloeError::Channel(format!(
                        "tcp send to {}: {e}",
                        self.endpoint
                    )))
                }
            }
        }
        unreachable!()
    }

    fn describe(&self) -> String {
        format!("tcp:{}#{}", self.endpoint, self.port_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_pair() -> (TcpReceiver, Arc<SyncQueue<Message>>, String) {
        let q = Arc::new(SyncQueue::new(64));
        let mut ports = HashMap::new();
        ports.insert("in".to_string(), Arc::clone(&q));
        let rx = TcpReceiver::start(0, ports).unwrap();
        let ep = rx.endpoint();
        (rx, q, ep)
    }

    #[test]
    fn roundtrip_over_tcp() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("one").with_key("k")).unwrap();
        tx.send(Message::f32s(vec![1.0, 2.0, 3.0])).unwrap();
        let a = q.pop().unwrap();
        assert_eq!(a.as_text(), Some("one"));
        assert_eq!(a.key.as_deref(), Some("k"));
        let b = q.pop().unwrap();
        assert_eq!(b.as_f32s(), Some(&[1.0f32, 2.0, 3.0][..]));
        rx.shutdown();
    }

    #[test]
    fn many_messages_in_order() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        for i in 0..500 {
            tx.send(Message::text(format!("m{i}"))).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop().unwrap().as_text(), Some(&*format!("m{i}")));
        }
        rx.shutdown();
    }

    #[test]
    fn unknown_port_dropped_known_delivered() {
        let (mut rx, q, ep) = start_pair();
        let bad = TcpSender::connect(&ep, "nope").unwrap();
        bad.send(Message::text("lost")).unwrap();
        let good = TcpSender::connect(&ep, "in").unwrap();
        good.send(Message::text("kept")).unwrap();
        assert_eq!(q.pop().unwrap().as_text(), Some("kept"));
        assert!(q.is_empty());
        rx.shutdown();
    }

    #[test]
    fn concurrent_senders() {
        let (mut rx, q, ep) = start_pair();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let ep = ep.clone();
                thread::spawn(move || {
                    let tx = TcpSender::connect(&ep, "in").unwrap();
                    for i in 0..100 {
                        tx.send(Message::text(format!("{t}-{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..400 {
            got.push(q.pop().unwrap().as_text().unwrap().to_string());
        }
        got.sort();
        got.dedup();
        assert_eq!(got.len(), 400);
        rx.shutdown();
    }
}
