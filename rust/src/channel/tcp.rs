//! TCP transport between flakes on different VMs/containers.
//!
//! Wire format per message frame (current, checksummed):
//! `[u32 total_len][u16 flags|port_len][port name bytes][message
//! bytes][u32 crc32]` with the message encoded by [`Message::encode`]
//! and the CRC-32 (IEEE) covering everything between the length
//! prefix and the trailer.  The high bit of the `u16` port-length
//! field ([`CHECKSUM_FLAG`]) marks the checksummed format; frames
//! with the bit clear are the legacy
//! `[u32 total_len][u16 port_len][port][message]` layout and are
//! still accepted, so mixed-version senders interoperate.  A
//! checksum mismatch is counted, the frame is dropped, and the
//! connection is closed — corruption surfaces as
//! drop-frame-and-reconnect, never as a misparsed message.  A
//! [`TcpReceiver`] listens on the flake's endpoint, decodes frames
//! and pushes them into the named input port queue; a [`TcpSender`]
//! holds one connection per (sink, port) pair.
//!
//! Both directions are batch-aware, allocation-slim and
//! event-driven on the shared I/O core.  [`TcpSender::send_batch`]
//! encodes every frame into a pooled buffer
//! ([`Message::encode_into`] — no per-message `Vec`), pushes it onto
//! a bounded per-sender egress queue and returns: a [`TxConn`] state
//! machine drains the queue on writability events with vectored
//! writes (adaptively coalescing multiple queued batches into one
//! syscall under load), so framing overlaps the kernel writes and a
//! slow peer blocks its producers only through the bounded queue —
//! never an OS thread per link.  The receiver reads
//! socket-buffer-sized chunks into one reusable accumulator, decodes
//! every complete frame, and delivers them per port with one
//! [`ShardedQueue::push_batch`].
//!
//! # Logical (rebindable) mode
//!
//! Both ends optionally address the sink **logically** through an
//! [`EndpointTable`] instead of holding physical handles:
//!
//! * [`TcpReceiver::start_logical`] resolves `(flake_id, port)` →
//!   queue through the table *per delivery* (cached per table
//!   version), so the same listening socket keeps feeding a flake
//!   across a relocation — the replacement republishes its queues
//!   under the same flake id and the next delivery lands there.  A
//!   push that races the relocation window (old queues closed, new
//!   ones not yet published) re-resolves with bounded backoff.
//! * [`TcpSender::logical`] resolves `floe://<flake-id>/<port>` → the
//!   sink's current `host:port` and watches the table version: when a
//!   relocation publishes a new physical endpoint, the sender first
//!   **drains its old connection in order** (shutdown the write half,
//!   wait for the receiver to finish decoding and close), then
//!   reconnects to the new endpoint — so per-producer FIFO survives
//!   the rebind.  Write failures retry through the same re-resolve
//!   path: fixed targets with bounded attempts, logical targets
//!   against a wall-clock deadline wide enough to bridge a failure
//!   *repair* (container death → lease expiry → `ReplaceFailed`
//!   respawn → republish), so upstream senders ride out the window
//!   instead of erroring into it.
//!
//! Delivery is at-least-once across reconnects: a connection that
//! breaks mid-buffer resends the in-flight batch buffers from the
//! start, so frames the receiver already consumed may arrive again.
//! Sinks that cannot tolerate duplicates dedupe on `Message::seq`.
//! When a sender's bounded retries are exhausted the pipeline drops
//! what it still holds and surfaces one error on the producer's next
//! send — the same contract the old synchronous path expressed by
//! erroring the batch it was carrying.

use std::collections::{HashMap, VecDeque};
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use crate::channel::{EndpointAddr, EndpointTable, ShardedQueue, Transport};
use crate::chaos::FrameFault;
use crate::error::{FloeError, Result};
use crate::message::Message;
use crate::util::crc::crc32;
use crate::util::netpoll::{source_fd, Conn, IoCore, Serve, Wake};
use crate::util::rng::Rng;

/// Hard ceiling on one frame (64 MiB) — rejects corrupt length prefixes.
const MAX_FRAME: usize = 64 << 20;

/// High bit of the wire `u16` port-length field: set on frames that
/// carry the CRC-32 trailer.  Legacy frames (bit clear) still decode.
const CHECKSUM_FLAG: u16 = 0x8000;

/// Receive chunk size: one read syscall can carry many small frames.
const READ_CHUNK: usize = 64 << 10;

/// Logical delivery: how many times a receiver re-resolves a sink
/// queue that is closed or unpublished (a relocation in flight) before
/// declaring the endpoint gone, and the pause between attempts.
const DELIVER_ATTEMPTS: usize = 1000;
const DELIVER_BACKOFF: Duration = Duration::from_millis(2);

/// Bounded send retry for fixed targets: attempts per batch
/// (reconnect + re-resolve between attempts, exponential backoff).
const SEND_ATTEMPTS: usize = 4;

/// Logical targets retry against this wall-clock deadline instead of
/// a fixed attempt count: the sink may be mid-*repair* (its container
/// died; the lease has to expire and `ReplaceFailed` respawn +
/// republish it), which is a far wider window than a reconnect blip.
const LOGICAL_SEND_DEADLINE: Duration = Duration::from_secs(5);

/// Cap on the exponential backoff between send retries.
const SEND_BACKOFF_CAP: Duration = Duration::from_millis(100);

/// Bound on draining the old connection during a logical rebind.
const REBIND_DRAIN_TIMEOUT: Duration = Duration::from_secs(2);

/// Default read-side idle deadline for data connections (ms): a
/// connection that delivers no bytes for this long is closed and its
/// slot reclaimed, so a half-open peer (crashed without FIN, wedged
/// mid-frame) cannot hold a registration forever.  Senders recover
/// transparently: the reuse-time staleness probe below notices the
/// close before the next batch is written.
const RX_IDLE_DEFAULT_MS: u64 = 60_000;

static RX_IDLE_LIMIT_MS: AtomicU64 = AtomicU64::new(RX_IDLE_DEFAULT_MS);

/// Override the read-side idle deadline process-wide (`None`
/// disables it).  Tests shrink it to exercise half-open reaping.
pub fn set_rx_idle_limit(limit: Option<Duration>) {
    let ms = limit.map_or(0, |d| (d.as_millis() as u64).max(1));
    RX_IDLE_LIMIT_MS.store(ms, Ordering::SeqCst);
}

fn rx_idle_limit_ms() -> u64 {
    RX_IDLE_LIMIT_MS.load(Ordering::Relaxed)
}

/// Default bound on a blocking batch write (ms).  A peer that
/// accepted but never reads (half-open) eventually fills both kernel
/// buffers and wedges `write_all` forever; this surfaces the stall as
/// an ordinary retryable send error instead.  Generous, so genuine
/// sink backpressure never trips it.
const WRITE_STALL_DEFAULT_MS: u64 = 30_000;

static WRITE_STALL_MS: AtomicU64 = AtomicU64::new(WRITE_STALL_DEFAULT_MS);

/// Override the sender write-stall bound process-wide (`None`
/// disables it).
pub fn set_write_stall_timeout(limit: Option<Duration>) {
    let ms = limit.map_or(0, |d| (d.as_millis() as u64).max(1));
    WRITE_STALL_MS.store(ms, Ordering::SeqCst);
}

fn write_stall_timeout() -> Option<Duration> {
    match WRITE_STALL_MS.load(Ordering::Relaxed) {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    }
}

/// Reusing a cached connection that has sat idle at least this long
/// first probes the read side for a peer close (EOF/reset), so a
/// batch is never written "successfully" into a socket the receiver
/// already idle-closed — that write would be silently lost.  Busy
/// senders never probe.
const STALE_PROBE_IDLE: Duration = Duration::from_secs(1);

/// Per-process sender counter: seeds each sender's retry-jitter
/// stream, so jitter is deterministic in sender creation order (and,
/// with a chaos plan armed, in the plan seed).
static SENDER_SEQ: AtomicU64 = AtomicU64::new(1);

fn sender_jitter_rng() -> Rng {
    let n = SENDER_SEQ.fetch_add(1, Ordering::Relaxed);
    let seed = crate::chaos::plan()
        .map(|p| p.seed())
        .unwrap_or(0x5EED_BAC0_FF5E_7u64);
    Rng::new(seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// How a receiver maps a frame's port name to a sink queue.
enum RxRoute {
    /// Physical: a port map captured at start (legacy / tests).
    Direct(HashMap<String, Arc<ShardedQueue<Message>>>),
    /// Logical: resolve `(flake_id, port)` through the endpoint table
    /// at delivery time — survives flake relocation.
    Logical { table: Arc<EndpointTable>, flake_id: String },
}

/// Idle-teardown state shared between the listener state machine and
/// the per-connection state machines.  Disabled by default; a
/// relocation replacement enables it on the lingering receivers it
/// adopts (their job is only to bridge not-yet-rebound senders), so
/// the listening socket and connection slots are reclaimed once every
/// sender has moved on.
struct IdleState {
    /// Idle window in ms; 0 = teardown disabled.
    timeout_ms: AtomicU64,
    /// Connections currently being served.
    active: AtomicUsize,
    /// ms since the receiver's epoch of the most recent connection
    /// close (or of the enable call) — the idle clock's start.
    last_close_ms: AtomicU64,
    torn_down: AtomicBool,
}

/// Listens for framed messages and pushes them into per-port input
/// queues.  The listener and every accepted connection run as state
/// machines on the process-wide event-driven I/O core
/// ([`IoCore::global`]) — a connection costs a poll-table slot and a
/// couple of reusable buffers, not an OS thread, so one ingress flake
/// scales to tens of thousands of concurrent senders with the thread
/// count pinned at the worker-pool size.
pub struct TcpReceiver {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    core: Arc<IoCore>,
    group: u64,
    idle: Arc<IdleState>,
    epoch: Instant,
}

impl TcpReceiver {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and route incoming frames into
    /// `ports` by port name.  Unknown ports are dropped with a log line.
    pub fn start(
        port: u16,
        ports: HashMap<String, Arc<ShardedQueue<Message>>>,
    ) -> Result<TcpReceiver> {
        TcpReceiver::start_with(port, RxRoute::Direct(ports))
    }

    /// Bind `127.0.0.1:port` (0 = ephemeral) and deliver incoming
    /// frames to whatever queues `table` maps `flake_id`'s ports to at
    /// delivery time (see the module docs on logical mode).
    pub fn start_logical(
        port: u16,
        flake_id: &str,
        table: Arc<EndpointTable>,
    ) -> Result<TcpReceiver> {
        TcpReceiver::start_with(
            port,
            RxRoute::Logical { table, flake_id: flake_id.to_string() },
        )
    }

    fn start_with(port: u16, route: RxRoute) -> Result<TcpReceiver> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let epoch = Instant::now();
        let idle = Arc::new(IdleState {
            timeout_ms: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            last_close_ms: AtomicU64::new(0),
            torn_down: AtomicBool::new(false),
        });
        let core = Arc::clone(IoCore::global());
        let group = core.new_group();
        let fd = source_fd(&listener);
        let sm = RxListener {
            listener,
            addr,
            route: Arc::new(route),
            stop: Arc::clone(&stop),
            idle: Arc::clone(&idle),
            epoch,
            group,
            accepts: 0,
            link: addr.to_string(),
        };
        // tick = true: the idle-teardown clock runs on the poller's
        // housekeeping ticks, not on a dedicated timer thread.
        core.register(group, fd, true, Box::new(sm))?;
        Ok(TcpReceiver { addr, stop, core, group, idle, epoch })
    }

    /// `host:port` of this receiver.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// Arm idle teardown: once no connection has been live for
    /// `timeout`, the accept loop exits and the listening socket
    /// closes.  Used on lingering receivers a relocation replacement
    /// adopts — they only exist to bridge senders that have not yet
    /// rebound, so when the last one disconnects the socket is
    /// reclaimed instead of lingering for the flake's lifetime.  The
    /// idle clock starts at this call.
    pub fn enable_idle_teardown(&self, timeout: Duration) {
        self.idle.last_close_ms.store(
            self.epoch.elapsed().as_millis() as u64,
            Ordering::SeqCst,
        );
        self.idle.timeout_ms.store(
            (timeout.as_millis() as u64).max(1),
            Ordering::SeqCst,
        );
    }

    /// Whether idle teardown already closed this receiver.
    pub fn is_torn_down(&self) -> bool {
        self.idle.torn_down.load(Ordering::SeqCst)
    }

    /// Retire the listener and every live connection of this
    /// receiver, waiting (bounded) for in-flight deliveries to
    /// finish.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.core.close_group(self.group, true);
    }
}

impl Drop for TcpReceiver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.core.close_group(self.group, false);
    }
}

/// Listener state machine: drains the kernel backlog into registered
/// [`RxConn`]s and runs the idle-teardown clock on poller ticks.
struct RxListener {
    listener: TcpListener,
    addr: std::net::SocketAddr,
    route: Arc<RxRoute>,
    stop: Arc<AtomicBool>,
    idle: Arc<IdleState>,
    epoch: Instant,
    group: u64,
    /// Lifetime accept count — the index stream for chaos
    /// connection-refusal decisions.
    accepts: u64,
    /// Stable link label (`host:port`) for chaos decisions.
    link: String,
}

impl RxListener {
    /// Drain the kernel backlog, registering one connection state
    /// machine per accepted socket.
    fn accept_ready(&mut self, core: &IoCore) -> Serve {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let accept_idx = self.accepts;
                    self.accepts += 1;
                    // Chaos: refuse = accept-then-drop; the sender
                    // sees an immediate close and retries.
                    if crate::chaos::rx_refuse_fault(
                        &self.link, accept_idx,
                    ) {
                        drop(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    self.idle.active.fetch_add(1, Ordering::SeqCst);
                    let fd = source_fd(&stream);
                    let conn = RxConn {
                        stream,
                        route: Arc::clone(&self.route),
                        stop: Arc::clone(&self.stop),
                        idle: Arc::clone(&self.idle),
                        epoch: self.epoch,
                        acc: Vec::with_capacity(READ_CHUNK),
                        chunk: vec![0u8; READ_CHUNK],
                        deliveries: Vec::new(),
                        last_read_ms: self.epoch.elapsed().as_millis()
                            as u64,
                    };
                    // A failed registration drops the state machine,
                    // whose Drop keeps the idle accounting balanced.
                    // Slow ticks drive the half-open idle deadline
                    // without a per-connection rearm every poll round.
                    let _ = core.register_slow(
                        self.group,
                        fd,
                        Box::new(conn),
                    );
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Serve::Continue;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => return Serve::Close,
            }
        }
    }
}

impl Conn for RxListener {
    fn wake(&mut self, _w: Wake, core: &IoCore) -> Serve {
        if self.stop.load(Ordering::SeqCst) {
            return Serve::Close;
        }
        // Accept *before* the idle-expiry decision: a sender whose
        // connection is still sitting unaccepted in the kernel
        // backlog at the deadline must be served, not severed (the
        // old accept loop checked the idle clock first and could
        // drop the listener over a non-empty backlog).
        if let Serve::Close = self.accept_ready(core) {
            return Serve::Close;
        }
        let timeout_ms = self.idle.timeout_ms.load(Ordering::SeqCst);
        if timeout_ms > 0
            && self.idle.active.load(Ordering::SeqCst) == 0
        {
            let now_ms = self.epoch.elapsed().as_millis() as u64;
            let last =
                self.idle.last_close_ms.load(Ordering::SeqCst);
            if now_ms.saturating_sub(last) >= timeout_ms {
                // Final backlog drain: a connect racing the deadline
                // itself is served (keeping the receiver alive)
                // instead of being severed by the teardown.
                if let Serve::Close = self.accept_ready(core) {
                    return Serve::Close;
                }
                if self.idle.active.load(Ordering::SeqCst) == 0 {
                    self.idle
                        .torn_down
                        .store(true, Ordering::SeqCst);
                    let addr = self.addr;
                    crate::log_info!(
                        "tcp: receiver {addr} idle for {timeout_ms} \
                         ms with every sender rebound; tearing down"
                    );
                    return Serve::Close; // retires the listener slot
                }
            }
        }
        Serve::Continue
    }
}

/// How many chunks one wake may read before yielding the worker: the
/// level-triggered poller re-offers a socket that still has bytes, so
/// one firehose connection cannot starve the rest of the pool.
const READ_BUDGET: usize = 16;

/// Per-connection state machine: owns the socket and the reusable
/// decode buffers; a partial frame simply stays in `acc` between
/// readiness events.
struct RxConn {
    stream: TcpStream,
    route: Arc<RxRoute>,
    stop: Arc<AtomicBool>,
    idle: Arc<IdleState>,
    epoch: Instant,
    /// Undecoded byte accumulator (partial frames carry across wakes).
    acc: Vec<u8>,
    /// Reusable read chunk.
    chunk: Vec<u8>,
    /// Reusable per-port delivery groups.
    deliveries: Vec<(String, Vec<Message>)>,
    /// ms since `epoch` of the last successful read — the per
    /// connection half-open idle clock, checked on slow ticks.
    last_read_ms: u64,
}

impl RxConn {
    /// Slow-tick housekeeping: reap the connection once it has
    /// delivered no bytes for the process-wide idle limit.  A peer
    /// that crashed without a FIN (half-open) or wedged mid-frame
    /// otherwise holds its poll slot forever.
    fn tick(&self) -> Serve {
        let limit = rx_idle_limit_ms();
        if limit == 0 {
            return Serve::Continue;
        }
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        if now_ms.saturating_sub(self.last_read_ms) >= limit {
            crate::telemetry::ctr_tcp_idle_closes().inc();
            crate::log_warn!(
                "tcp: closing half-open connection (no bytes for \
                 {limit} ms{})",
                if self.acc.is_empty() {
                    ""
                } else {
                    ", partial frame pending"
                }
            );
            return Serve::Close;
        }
        Serve::Continue
    }
}

impl Conn for RxConn {
    fn wake(&mut self, w: Wake, _core: &IoCore) -> Serve {
        if self.stop.load(Ordering::SeqCst) {
            return Serve::Close;
        }
        if let Wake::Tick = w {
            return self.tick();
        }
        // Chaos: a read stall leaves the socket readable but unread —
        // the injected half of a half-open link.
        if crate::chaos::rx_read_stalled() {
            thread::sleep(Duration::from_millis(1));
            return Serve::Continue;
        }
        for _ in 0..READ_BUDGET {
            let n = match self.stream.read(&mut self.chunk) {
                Ok(0) => {
                    // Peer closed.  Bytes left in the accumulator
                    // mean the peer died mid-frame — surface the
                    // data loss instead of treating it as a clean
                    // shutdown.
                    if !self.acc.is_empty() {
                        crate::log_warn!(
                            "tcp: peer closed mid-frame ({} byte(s) \
                             undecoded)",
                            self.acc.len()
                        );
                    }
                    return Serve::Close;
                }
                Ok(n) => n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Serve::Continue;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted =>
                {
                    continue;
                }
                Err(_) => return Serve::Close, // peer reset
            };
            self.acc.extend_from_slice(&self.chunk[..n]);
            self.last_read_ms =
                self.epoch.elapsed().as_millis() as u64;
            if crate::telemetry::enabled() {
                crate::telemetry::ctr_tcp_rx_bytes().add(n as u64);
            }
            match decode_and_deliver(
                &mut self.acc,
                &mut self.deliveries,
                &self.route,
                &self.stop,
            ) {
                Ok(true) => {}
                Ok(false) => return Serve::Close, // sink gone
                Err(e) => {
                    crate::log_warn!(
                        "tcp: closing connection on corrupt \
                         frame: {e}"
                    );
                    return Serve::Close;
                }
            }
        }
        Serve::Continue
    }
}

impl Drop for RxConn {
    fn drop(&mut self) {
        // Close stamp *before* the decrement: the idle check only
        // reads the clock when active == 0, so it must already be
        // fresh by then.  Drop runs on every retire path (EOF,
        // error, close_group), so the accounting is exactly-once.
        self.idle.last_close_ms.store(
            self.epoch.elapsed().as_millis() as u64,
            Ordering::SeqCst,
        );
        self.idle.active.fetch_sub(1, Ordering::SeqCst);
    }
}

enum Delivered {
    Ok,
    /// The sink is gone for good — end the connection.
    SinkGone,
}

/// Hand one per-port batch to its sink queue.
///
/// Logical mode delivers **one message at a time via `try_push`**,
/// which hands the message back on failure — unlike `push_batch`,
/// which can partially enqueue before a racing close, a per-message
/// push is atomic with respect to the relocation handoff, so a prefix
/// the captured backlog already holds can never be delivered twice.
/// A full-but-open queue is ordinary backpressure (wait, like the
/// blocking push); a closed or vanished queue re-resolves through the
/// table with bounded backoff so the delivery follows a relocation's
/// republish.  An unknown *port* on a live flake is permanent: the
/// batch is dropped with a warning and the connection stays up,
/// matching the direct path.
fn deliver(
    route: &RxRoute,
    port: &str,
    batch: Vec<Message>,
    stop: &AtomicBool,
) -> Delivered {
    match route {
        RxRoute::Direct(ports) => match ports.get(port) {
            Some(q) => {
                if q.push_batch(batch).is_err() {
                    Delivered::SinkGone // flake shut down
                } else {
                    Delivered::Ok
                }
            }
            None => {
                crate::log_warn!(
                    "tcp: dropping {} message(s) for unknown port \
                     {port}",
                    batch.len()
                );
                Delivered::Ok
            }
        },
        RxRoute::Logical { table, flake_id } => {
            let mut attempts = 0usize;
            let mut iter = batch.into_iter();
            let mut pending = iter.next();
            while let Some(msg) = pending.take() {
                match table.resolve_queue(flake_id, port) {
                    Some(q) => match q.try_push(msg) {
                        Ok(()) => {
                            attempts = 0;
                            pending = iter.next();
                            continue;
                        }
                        Err(back) => {
                            pending = Some(back);
                            if !q.is_closed() {
                                // Plain backpressure on a live queue:
                                // wait it out like a blocking push.
                                if stop.load(Ordering::SeqCst) {
                                    return Delivered::SinkGone;
                                }
                                thread::sleep(DELIVER_BACKOFF);
                                continue;
                            }
                            // Closed: relocation handoff in flight —
                            // fall through and re-resolve.
                        }
                    },
                    None if table.has_flake(flake_id) => {
                        // Live flake, unknown port: permanent.
                        crate::log_warn!(
                            "tcp: dropping {} message(s) for unknown \
                             port {flake_id}/{port}",
                            1 + iter.len()
                        );
                        return Delivered::Ok;
                    }
                    None => {} // flake gone; retry briefly below
                }
                attempts += 1;
                if attempts > DELIVER_ATTEMPTS
                    || stop.load(Ordering::SeqCst)
                {
                    crate::log_warn!(
                        "tcp: dropping {} message(s) for \
                         {flake_id}/{port} (endpoint unresolvable)",
                        1 + iter.len()
                    );
                    return Delivered::SinkGone;
                }
                thread::sleep(DELIVER_BACKOFF);
            }
            Delivered::Ok
        }
    }
}

/// Decode every complete frame in `acc`, grouping consecutive
/// messages per port so each group lands in the sink queue through
/// one batch push, then deliver the groups.  Consumed bytes are
/// drained from `acc`; a partial trailing frame stays for the next
/// read.  Returns `Ok(true)` to keep the connection, `Ok(false)` when
/// the sink is gone, or `Err` on a corrupt frame — everything decoded
/// before the corruption is still delivered.
fn decode_and_deliver(
    acc: &mut Vec<u8>,
    deliveries: &mut Vec<(String, Vec<Message>)>,
    route: &RxRoute,
    stop: &AtomicBool,
) -> Result<bool> {
    let mut consumed = 0usize;
    let mut decoded_frames = 0u64;
    let mut frame_err: Option<FloeError> = None;
    loop {
        let avail = acc.len() - consumed;
        if avail < 4 {
            break;
        }
        let total = u32::from_le_bytes(
            acc[consumed..consumed + 4].try_into().expect("4 bytes"),
        ) as usize;
        if total < 2 || total > MAX_FRAME {
            frame_err = Some(FloeError::Channel(format!(
                "tcp: bad frame length {total}"
            )));
            break;
        }
        if avail < 4 + total {
            break; // incomplete frame; wait for more bytes
        }
        let frame = &acc[consumed + 4..consumed + 4 + total];
        let raw = u16::from_le_bytes([frame[0], frame[1]]);
        let checked = raw & CHECKSUM_FLAG != 0;
        let port_len = (raw & !CHECKSUM_FLAG) as usize;
        // Checksummed frames verify the CRC-32 trailer before any
        // byte is interpreted; legacy frames (flag clear) skip it.
        let body_end = if checked {
            if total < 2 + 4 {
                frame_err = Some(FloeError::Channel(
                    "tcp: checksummed frame too short".into(),
                ));
                break;
            }
            let end = frame.len() - 4;
            let want = u32::from_le_bytes(
                frame[end..].try_into().expect("4 bytes"),
            );
            if crc32(&frame[..end]) != want {
                crate::telemetry::ctr_tcp_corrupt_frames().inc();
                frame_err = Some(FloeError::Channel(
                    "tcp: frame checksum mismatch".into(),
                ));
                break;
            }
            end
        } else {
            frame.len()
        };
        if 2 + port_len > body_end {
            frame_err = Some(FloeError::Channel(
                "tcp: bad port length".into(),
            ));
            break;
        }
        let port = &frame[2..2 + port_len];
        let msg = match Message::decode(&frame[2 + port_len..body_end])
        {
            Ok(m) => m,
            Err(e) => {
                frame_err = Some(e);
                break;
            }
        };
        // The port name String is allocated once per run of
        // same-port frames, not once per frame.
        let same_port = matches!(
            deliveries.last(), Some((p, _)) if p.as_bytes() == port
        );
        if same_port {
            deliveries.last_mut().expect("non-empty").1.push(msg);
        } else {
            let port = String::from_utf8_lossy(port).into_owned();
            deliveries.push((port, vec![msg]));
        }
        consumed += 4 + total;
        decoded_frames += 1;
    }
    if decoded_frames > 0 && crate::telemetry::enabled() {
        crate::telemetry::ctr_tcp_rx_frames().add(decoded_frames);
    }
    if consumed > 0 {
        acc.drain(..consumed);
    }
    for (port, batch) in deliveries.drain(..) {
        match deliver(route, &port, batch, stop) {
            Delivered::Ok => {}
            Delivered::SinkGone => return Ok(false),
        }
    }
    if let Some(e) = frame_err {
        return Err(e);
    }
    Ok(true)
}

/// Blocking read loop over the same decode/deliver machinery —
/// test-only stand-in for a served connection (production
/// connections run as [`RxConn`] state machines on the I/O core).
#[cfg(test)]
fn serve_blocking(
    mut stream: TcpStream,
    route: &RxRoute,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut acc: Vec<u8> = Vec::with_capacity(READ_CHUNK);
    let mut chunk = vec![0u8; READ_CHUNK];
    let mut deliveries: Vec<(String, Vec<Message>)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let n = match stream.read(&mut chunk) {
            Ok(0) => return Ok(()),
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return Ok(()), // peer reset
        };
        acc.extend_from_slice(&chunk[..n]);
        if !decode_and_deliver(&mut acc, &mut deliveries, route, stop)?
        {
            return Ok(());
        }
    }
    Ok(())
}

/// Don't let one giant batch pin a huge scratch buffer forever.
const SCRATCH_KEEP: usize = 1 << 20;

/// Shrink an oversized recycled egress buffer only after this many
/// *consecutive* batches framed below [`SCRATCH_KEEP`].  A steady
/// large-batch workload keeps its capacity (the old policy shrank
/// after every oversized send, reallocating each cycle), while a
/// workload that genuinely shrank gives the memory back after a
/// short streak.
const SHRINK_AFTER: u32 = 8;

/// Recycled egress buffers kept per sender: the producer frames into
/// one buffer while the I/O core writes the previous ones — double
/// buffering, generalized to a small pool.
const POOL_KEEP: usize = 4;

/// Default per-sender egress queue bound in bytes (queued plus
/// in-flight).  A full queue blocks the producer inside
/// [`TcpSender::send_all`] — zero-loss backpressure, never dropping.
/// A single batch larger than the cap is admitted alone (the queue
/// momentarily overshoots by one batch rather than deadlocking).
const EGRESS_CAP_DEFAULT: usize = 4 << 20;

static EGRESS_CAP: AtomicUsize = AtomicUsize::new(EGRESS_CAP_DEFAULT);

/// Override the per-sender egress queue byte bound process-wide
/// (`None` restores the default).  Tests shrink it to exercise
/// backpressure; benches widen it to measure pipelining.
pub fn set_egress_queue_cap(cap: Option<usize>) {
    EGRESS_CAP.store(
        cap.unwrap_or(EGRESS_CAP_DEFAULT).max(1),
        Ordering::SeqCst,
    );
}

fn egress_queue_cap() -> usize {
    EGRESS_CAP.load(Ordering::Relaxed)
}

/// Bounds on one coalesced flush: at most this many queued batch
/// buffers gathered into a single vectored write, and at most
/// [`COALESCE_BYTES`] bytes in flight at once.  When the queue is
/// shallow each batch flushes immediately (no added latency); when
/// producers outrun the peer, batches accumulate and each
/// writability event drains up to the bound — adaptive coalescing.
const TX_VECTORS: usize = 16;
const COALESCE_BYTES: usize = 1 << 20;

/// Vectored flushes one egress state machine performs per wake
/// before yielding its worker (fairness across connections sharing
/// the I/O core pool).
const WRITE_BUDGET: usize = 16;

/// Process-wide count of queued / in-flight egress batch buffers,
/// mirrored into the `floe_channel_tcp_egress_queue_depth` gauge
/// (the registry gauge is set-only, so the true count lives here).
static EGRESS_DEPTH: AtomicU64 = AtomicU64::new(0);

fn egress_depth_add(n: u64) {
    if n == 0 {
        return;
    }
    let d = EGRESS_DEPTH.fetch_add(n, Ordering::Relaxed) + n;
    if crate::telemetry::enabled() {
        crate::telemetry::gauge_tcp_egress_queue().set(d);
    }
}

fn egress_depth_sub(n: u64) {
    if n == 0 {
        return;
    }
    let d =
        EGRESS_DEPTH.fetch_sub(n, Ordering::Relaxed).saturating_sub(n);
    if crate::telemetry::enabled() {
        crate::telemetry::gauge_tcp_egress_queue().set(d);
    }
}

/// Where a sender finds its peer.
enum SenderTarget {
    /// Physical `host:port`, fixed for the sender's lifetime.
    Fixed(String),
    /// Logical: re-resolve the sink flake's current `host:port`
    /// through the endpoint table on every version bump.
    Logical { table: Arc<EndpointTable>, flake_id: String },
}

/// One entry in a sender's egress queue.
enum TxItem {
    /// A framed batch: the buffer and how many logical messages it
    /// carries (for the tx-frames counter on flush).
    Data { buf: Vec<u8>, frames: u64 },
    /// Chaos cut marker: sever the connection at exactly this point
    /// in the byte stream (drain the old socket in order, reconnect
    /// fresh) so injected drops / resets / corruption keep their
    /// position relative to the batches around them.
    Cut,
}

/// Producer-visible state of one egress pipeline, shared between the
/// `TcpSender` handle and its [`TxConn`] state machine.
struct TxState {
    items: VecDeque<TxItem>,
    /// Bytes enqueued plus in flight — the backpressure meter.
    queued_bytes: usize,
    /// Drained buffers recycled back to producers (see [`POOL_KEEP`]).
    pool: Vec<Vec<u8>>,
    /// Consecutive drained batches below [`SCRATCH_KEEP`].
    shrink_streak: u32,
    /// The TxConn parked on an empty queue: the next producer to
    /// enqueue must kick it awake.
    parked: bool,
    /// A TxConn state machine currently owns this state.
    live: bool,
    /// The sender handle was dropped: drain the queue fully, then
    /// FIN and retire.
    shutdown: bool,
    /// The TxConn gave up (bounded retries exhausted).  The next
    /// `send_all` surfaces this error once, then respawns a fresh
    /// pipeline.
    broken: Option<String>,
    /// Spawn generation: lets a retiring TxConn tell whether the
    /// state still belongs to it (a respawn may have taken over).
    epoch: u64,
    /// Chaos frame / batch indices (monotone per sender) and the
    /// stash of the previous clean frame for reorder replays.
    chaos_frame: u64,
    chaos_batch: u64,
    chaos_stash: Vec<u8>,
}

impl TxState {
    fn new() -> TxState {
        TxState {
            items: VecDeque::new(),
            queued_bytes: 0,
            pool: Vec::new(),
            shrink_streak: 0,
            parked: false,
            live: false,
            shutdown: false,
            broken: None,
            epoch: 0,
            chaos_frame: 0,
            chaos_batch: 0,
            chaos_stash: Vec::new(),
        }
    }
}

/// Handle shared between a `TcpSender` (producer side) and its
/// [`TxConn`] (I/O-core side).
struct TxShared {
    state: Mutex<TxState>,
    /// Signaled whenever queue space frees up or the pipeline dies.
    space: Condvar,
    /// The TxConn's netpoll token (0 until registration completes —
    /// the spawner kicks once the token is published).
    token: AtomicU64,
}

/// Return a drained buffer to the producer pool, shrinking an
/// oversized one only after [`SHRINK_AFTER`] consecutive batches
/// below the [`SCRATCH_KEEP`] watermark.
fn recycle_buf(st: &mut TxState, mut buf: Vec<u8>) {
    if buf.capacity() > SCRATCH_KEEP {
        if buf.len() >= SCRATCH_KEEP {
            st.shrink_streak = 0;
        } else {
            st.shrink_streak += 1;
            if st.shrink_streak >= SHRINK_AFTER {
                buf.shrink_to(SCRATCH_KEEP);
                st.shrink_streak = 0;
            }
        }
    }
    buf.clear();
    if st.pool.len() < POOL_KEEP {
        st.pool.push(buf);
    }
}

/// Sends framed messages to one sink flake's input port over TCP.
///
/// Since the egress-pipeline rewrite this is the *producer half*
/// only: `send_all` frames the batch into a pooled buffer, pushes it
/// onto a bounded per-sender egress queue and returns without
/// touching the socket.  A [`TxConn`] state machine on the shared
/// [`IoCore`] owns the connection and drains the queue on
/// writability events, so framing the next batch overlaps the kernel
/// write of the previous one.  A full queue blocks the producer
/// (zero-loss backpressure); connection failures surface on a later
/// `send_all` once the TxConn's bounded retries are exhausted.
pub struct TcpSender {
    target: Arc<SenderTarget>,
    port_name: String,
    shared: Arc<TxShared>,
}

impl TcpSender {
    /// Connect to a fixed physical endpoint (`host:port`).
    pub fn connect(endpoint: &str, port_name: &str) -> Result<TcpSender> {
        let stream = TcpStream::connect(endpoint)?;
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Self::with_pipeline(
            SenderTarget::Fixed(endpoint.to_string()),
            port_name,
            Some(endpoint.to_string()),
            0,
            Some(stream),
        )
    }

    /// Connect to the logical address `floe://<flake-id>/<port>`,
    /// resolving (and re-resolving, on every table version bump) the
    /// sink's physical endpoint through `table`.  See the module docs
    /// for the rebind sequence.
    pub fn logical(
        table: Arc<EndpointTable>,
        addr: &EndpointAddr,
    ) -> Result<TcpSender> {
        let (seen_version, endpoint) = table
            .resolve_tcp_versioned(&addr.flake_id)
            .ok_or_else(|| {
                FloeError::Channel(format!(
                    "tcp: {addr} has no published tcp endpoint"
                ))
            })?;
        let stream = TcpStream::connect(&endpoint)?;
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Self::with_pipeline(
            SenderTarget::Logical {
                table,
                flake_id: addr.flake_id.clone(),
            },
            &addr.port,
            Some(endpoint),
            seen_version,
            Some(stream),
        )
    }

    /// Common tail of the constructors: build the shared egress
    /// state and hand the (already connected, nonblocking) socket to
    /// a fresh [`TxConn`] on the I/O core.
    fn with_pipeline(
        target: SenderTarget,
        port_name: &str,
        endpoint: Option<String>,
        seen_version: u64,
        stream: Option<TcpStream>,
    ) -> Result<TcpSender> {
        let target = Arc::new(target);
        let shared = Arc::new(TxShared {
            state: Mutex::new(TxState::new()),
            space: Condvar::new(),
            token: AtomicU64::new(0),
        });
        spawn_tx_conn(&target, &shared, endpoint, seen_version, stream)?;
        Ok(TcpSender {
            target,
            port_name: port_name.to_string(),
            shared,
        })
    }

    /// Append one frame, encoding the message straight into `out`
    /// (no intermediate body buffer): the length prefix is written as a
    /// placeholder and backpatched once the encoded size is known.
    /// Emits the checksummed format — [`CHECKSUM_FLAG`] set in the
    /// port-length field, CRC-32 trailer over flags + port + message.
    fn frame_into(port_name: &str, msg: &Message, out: &mut Vec<u8>) {
        let len_at = out.len();
        out.extend_from_slice(&[0u8; 4]); // total-length placeholder
        out.extend_from_slice(
            &(port_name.len() as u16 | CHECKSUM_FLAG).to_le_bytes(),
        );
        out.extend_from_slice(port_name.as_bytes());
        msg.encode_into(out);
        let crc = crc32(&out[len_at + 4..]);
        out.extend_from_slice(&crc.to_le_bytes());
        let total = (out.len() - len_at - 4) as u32;
        out[len_at..len_at + 4].copy_from_slice(&total.to_le_bytes());
    }

    /// Frame `msgs` into a pooled buffer and enqueue it on the
    /// egress pipeline — nonblocking in the common case.  The only
    /// waits are backpressure (bounded queue full) and surfacing a
    /// previous pipeline failure; the socket syscalls themselves all
    /// happen on the I/O core.
    fn send_all(&self, msgs: &[Message]) -> Result<()> {
        let mut st = self.admit()?;
        let mut buf = st.pool.pop().unwrap_or_default();
        buf.clear();
        let (cut_before, cut_after) = if crate::chaos::armed() {
            self.frame_with_chaos(&mut st, &mut buf, msgs)
        } else {
            for msg in msgs {
                Self::frame_into(&self.port_name, msg, &mut buf);
            }
            (false, false)
        };
        if cut_before {
            // Injected drop/reset: a cut marker *before* the batch —
            // the TxConn severs (drain handshake included) and then
            // transmits the batch on a fresh connection, so the
            // injected fault keeps its position in the stream and
            // the resend stays in order.
            st.items.push_back(TxItem::Cut);
        }
        st.queued_bytes += buf.len();
        st.items.push_back(TxItem::Data {
            buf,
            frames: msgs.len() as u64,
        });
        if cut_after {
            // Injected corruption: the receiver closes on detecting
            // the bad trailer copy, so retire the connection in
            // order right after this batch flushes.
            st.items.push_back(TxItem::Cut);
        }
        egress_depth_add(1);
        let kick = st.parked;
        if kick {
            st.parked = false;
        }
        drop(st);
        if kick {
            IoCore::global()
                .kick(self.shared.token.load(Ordering::SeqCst));
        }
        Ok(())
    }

    /// Gate a producer into the egress queue: surface a pipeline
    /// failure exactly once (a fresh pipeline respawns on the next
    /// call), and block while the bounded queue is full — zero-loss
    /// backpressure, never dropping.
    fn admit(&self) -> Result<MutexGuard<'_, TxState>> {
        loop {
            let mut st =
                self.shared.state.lock().expect("tcp sender poisoned");
            if let Some(e) = st.broken.take() {
                return Err(FloeError::Channel(e));
            }
            if !st.live {
                // Spawning locks the state itself, so release first.
                drop(st);
                spawn_tx_conn(&self.target, &self.shared, None, 0, None)?;
                continue;
            }
            while st.live
                && st.broken.is_none()
                && st.queued_bytes >= egress_queue_cap()
            {
                let (g, _) = self
                    .shared
                    .space
                    .wait_timeout(st, Duration::from_millis(100))
                    .expect("tcp sender poisoned");
                st = g;
            }
            if st.broken.is_some() || !st.live {
                continue; // handled at the top of the loop
            }
            return Ok(st);
        }
    }

    /// Frame `msgs` while consulting the armed fault plan, mutating
    /// the batch buffer in place.  Returns `(cut_before,
    /// cut_after)`: cut the connection before the batch transmits
    /// (drop / reset — the resend stays in order) and/or after it
    /// (corruption — the receiver is about to close its end anyway).
    /// On the pipelined path the cuts travel through the egress
    /// queue as [`TxItem::Cut`] markers, so faults are decided at
    /// framing/enqueue time but applied at exactly the right point
    /// in the byte stream.
    ///
    /// Fault mechanics, chosen so the system-level guarantees stay
    /// checkable (zero loss, per-producer FIFO modulo duplicates):
    ///
    /// * **drop / reset** — drain-cut the connection; the whole batch
    ///   is retried in order.  Loss would only occur if retries were
    ///   also exhausted, which the tests treat as a failure.
    /// * **delay** — sleep before the write (stretches the batch's
    ///   latency, reordering it against *other* producers only).
    /// * **duplicate** — the frame is appended twice; sinks dedupe on
    ///   `Message::seq`.
    /// * **reorder** — a *stale retransmit*: the previous clean frame
    ///   is replayed before the current one, modelling a late
    ///   duplicate from an earlier connection.  (Swapping two fresh
    ///   frames instead would make the watermark dedup filter drop
    ///   the older one — genuine loss, not reordering.)
    /// * **corrupt** — a *corrupted extra copy* of the frame (one
    ///   byte past its length prefix flipped after the CRC trailer
    ///   was computed, so the checksum check is guaranteed to fire)
    ///   is transmitted after the whole clean batch.  The receiver
    ///   decodes every clean frame, detects the corruption and closes
    ///   the connection; the sender drain-cuts afterwards so the next
    ///   batch starts on a fresh connection.  Corrupting the frame
    ///   *in place* instead would silently lose it: the write
    ///   succeeds, so the sender never retries.
    fn frame_with_chaos(
        &self,
        st: &mut TxState,
        out: &mut Vec<u8>,
        msgs: &[Message],
    ) -> (bool, bool) {
        let link = self.describe();
        let batch_idx = st.chaos_batch;
        st.chaos_batch += 1;
        let mut cut_before =
            crate::chaos::tx_reset_fault(&link, batch_idx);
        let mut corrupt_tail: Vec<u8> = Vec::new();
        for msg in msgs {
            let idx = st.chaos_frame;
            st.chaos_frame += 1;
            let start = out.len();
            Self::frame_into(&self.port_name, msg, out);
            let flen = out.len() - start;
            let fault = crate::chaos::tx_frame_fault(&link, idx);
            if let FrameFault::Reorder = fault {
                if !st.chaos_stash.is_empty() {
                    // Splice the stale frame in *before* the current
                    // one: take current out, append stash, restore.
                    let cur = out.split_off(start);
                    out.extend_from_slice(&st.chaos_stash);
                    out.extend_from_slice(&cur);
                }
            }
            // Stash the clean frame for a future reorder replay.
            let end = out.len();
            st.chaos_stash.clear();
            st.chaos_stash.extend_from_slice(&out[end - flen..end]);
            match fault {
                FrameFault::None | FrameFault::Reorder => {}
                FrameFault::Drop => cut_before = true,
                FrameFault::Delay(ms) => {
                    thread::sleep(Duration::from_millis(ms));
                }
                FrameFault::Duplicate => {
                    out.extend_from_within(end - flen..end);
                }
                FrameFault::Corrupt(salt) => {
                    let at = corrupt_tail.len();
                    corrupt_tail
                        .extend_from_slice(&out[end - flen..end]);
                    // Flip a byte past the length prefix (corrupting
                    // the prefix itself would desync framing — a
                    // different failure mode).
                    let span = flen - 4;
                    corrupt_tail[at + 4 + (salt as usize % span)] ^=
                        0x20;
                }
            }
        }
        let cut_after = !corrupt_tail.is_empty();
        out.extend_from_slice(&corrupt_tail);
        (cut_before, cut_after)
    }
}

impl Drop for TcpSender {
    /// Flag the pipeline for shutdown and wake the TxConn: it drains
    /// everything still queued, then drops the socket — so the FIN
    /// the receiver sees always trails the last queued frame.
    fn drop(&mut self) {
        let live = match self.shared.state.lock() {
            Ok(mut st) => {
                st.shutdown = true;
                st.parked = false;
                st.live
            }
            Err(_) => false,
        };
        if live {
            IoCore::global()
                .kick(self.shared.token.load(Ordering::SeqCst));
        }
    }
}

/// Register a fresh [`TxConn`] on the global I/O core, taking over
/// the shared egress state (bumping its spawn epoch).  With no
/// stream the slot starts detached (`fd = -1`) and connects on its
/// first wake; the unconditional kick below guarantees that wake —
/// and closes the window where a connected socket's first writable
/// event fires before the token is published (the TxConn parks on
/// `token == 0` and the kick re-delivers).
///
/// Must not be called with the shared state lock held: both this
/// function and the error-path drop of the boxed TxConn take it.
fn spawn_tx_conn(
    target: &Arc<SenderTarget>,
    shared: &Arc<TxShared>,
    endpoint: Option<String>,
    seen_version: u64,
    stream: Option<TcpStream>,
) -> Result<()> {
    let core = IoCore::global();
    let fd = stream.as_ref().map_or(-1, source_fd);
    let epoch = {
        let mut st =
            shared.state.lock().expect("tcp sender poisoned");
        st.epoch += 1;
        st.live = true;
        st.parked = false;
        st.epoch
    };
    let conn = TxConn {
        shared: Arc::clone(shared),
        target: Arc::clone(target),
        epoch,
        endpoint,
        seen_version,
        stream,
        inflight: Vec::new(),
        head_written: 0,
        pending_cut: false,
        last_write: Instant::now(),
        jitter: sender_jitter_rng(),
        attempt: 0,
        episode_deadline: None,
        last_err: String::new(),
        backoff_until: None,
        stall_since: None,
    };
    let group = core.new_group();
    let token = core.register_writable(group, fd, Box::new(conn))?;
    shared.token.store(token, Ordering::SeqCst);
    core.kick(token);
    Ok(())
}

/// What [`TxConn::gather`] found at the head of the egress queue.
enum Gathered {
    /// Batches were moved into the in-flight window.
    Data,
    /// A chaos cut marker is next: sever before writing further.
    Cut,
    /// Nothing queued; `shutdown` says whether to retire or park.
    Empty { shutdown: bool },
}

/// Result of one vectored flush attempt.
enum FlushOutcome {
    /// Bytes were handed to the kernel.
    Progress,
    /// Kernel buffer full (`EWOULDBLOCK`).
    Blocked,
    /// `EINTR` — retry immediately.
    Retry,
    /// The connection is dead.
    Broken(String),
}

/// The I/O-core state machine owning one egress connection: it pops
/// framed buffers off the shared queue and writes them with vectored
/// syscalls on writability events, and it owns every slow path the
/// old blocking sender ran inline — reconnect with jittered backoff
/// (via poll-thread timers, so no worker ever sleeps), logical
/// re-resolve + the in-order rebind drain, stale-socket probing,
/// write-stall deadlines, chaos cuts and the final give-up.
struct TxConn {
    shared: Arc<TxShared>,
    target: Arc<SenderTarget>,
    /// Spawn generation (see [`TxState::epoch`]).
    epoch: u64,
    endpoint: Option<String>,
    seen_version: u64,
    stream: Option<TcpStream>,
    /// Buffers popped from the queue but not yet fully written,
    /// owned here so a broken connection resends them in order.
    inflight: Vec<(Vec<u8>, u64)>,
    /// Bytes of `inflight[0]` already handed to the kernel.
    head_written: usize,
    /// A [`TxItem::Cut`] was popped: sever before the next write.
    pending_cut: bool,
    /// When this connection last carried a successful write —
    /// drives the reuse-time staleness probe.
    last_write: Instant,
    /// Seeded retry-jitter stream (see [`sender_jitter_rng`]).
    jitter: Rng,
    /// Consecutive failures in the current reconnect episode.
    attempt: usize,
    /// Logical targets: wall-clock bound on the current episode.
    episode_deadline: Option<Instant>,
    last_err: String,
    /// Backoff gate: park (spurious wakes included) until this
    /// instant; a `kick_in` timer re-delivers the wake.
    backoff_until: Option<Instant>,
    /// First `EWOULDBLOCK` of the current stall, if any.
    stall_since: Option<Instant>,
}

impl Conn for TxConn {
    fn wake(&mut self, _w: Wake, core: &IoCore) -> Serve {
        if self.token() == 0 {
            // Registration still completing; the spawner kicks once
            // the token is published.
            return Serve::Park;
        }
        let mut budget = WRITE_BUDGET;
        loop {
            if let Some(until) = self.backoff_until {
                if Instant::now() < until {
                    // Still backing off — the kick_in timer already
                    // scheduled re-wakes us; spurious wakes (e.g. a
                    // producer kick) land here and park again.
                    return Serve::Park;
                }
                self.backoff_until = None;
            }
            if self.inflight.is_empty() && !self.pending_cut {
                match self.gather() {
                    Gathered::Cut => self.pending_cut = true,
                    Gathered::Data => {}
                    Gathered::Empty { shutdown: true } => {
                        // Fully drained after the sender dropped:
                        // retiring drops the socket, so the FIN the
                        // receiver sees trails the last frame.
                        return Serve::Close;
                    }
                    Gathered::Empty { shutdown: false } => {
                        return Serve::Park;
                    }
                }
            }
            if self.pending_cut {
                self.sever(core);
                self.pending_cut = false;
            }
            if let Err(e) = self.refresh(core) {
                return self.retry_or_give_up(core, e);
            }
            if self.stream.is_none() {
                if let Err(e) = self.reconnect(core) {
                    return self.retry_or_give_up(core, e);
                }
            } else if self.head_written == 0
                && self.last_write.elapsed() >= STALE_PROBE_IDLE
                && stream_stale(self.stream.as_mut().expect("probed"))
            {
                // Reuse-time staleness probe: an idle connection may
                // have been closed by the receiver (idle deadline,
                // restart) — a write into it would "succeed" into a
                // reset-bound socket and be lost.
                crate::log_debug!(
                    "tcp: cached egress connection went stale while \
                     idle; reconnecting"
                );
                self.drop_stream(core);
                continue;
            }
            match self.flush_inflight() {
                FlushOutcome::Progress => {
                    budget -= 1;
                    if budget == 0 {
                        // Yield the worker for fairness; writable
                        // interest re-arms and the next event
                        // resumes the drain.
                        return Serve::Continue;
                    }
                }
                FlushOutcome::Retry => {}
                FlushOutcome::Blocked => {
                    return self.on_blocked(core);
                }
                FlushOutcome::Broken(err) => {
                    let ep =
                        self.endpoint.clone().unwrap_or_default();
                    crate::log_debug!(
                        "tcp send to {ep} failed ({err}), retrying"
                    );
                    self.drop_stream(core);
                    let e = FloeError::Channel(format!(
                        "tcp send to {ep}: {err}"
                    ));
                    return self.retry_or_give_up(core, e);
                }
            }
        }
    }
}

impl TxConn {
    fn token(&self) -> u64 {
        self.shared.token.load(Ordering::SeqCst)
    }

    /// Move queued batches into the in-flight window, bounded by
    /// [`TX_VECTORS`] buffers / [`COALESCE_BYTES`] bytes.  Stops at
    /// a [`TxItem::Cut`], which is only consumed once everything
    /// before it has flushed.  Parking is decided under the state
    /// lock, so a concurrent enqueue either sees `parked` (and
    /// kicks) or pushed in time to be gathered here.
    fn gather(&mut self) -> Gathered {
        let mut st =
            self.shared.state.lock().expect("tcp sender poisoned");
        if self.inflight.is_empty() {
            if let Some(TxItem::Cut) = st.items.front() {
                st.items.pop_front();
                return Gathered::Cut;
            }
        }
        let mut bytes: usize =
            self.inflight.iter().map(|(b, _)| b.len()).sum();
        while self.inflight.len() < TX_VECTORS
            && bytes < COALESCE_BYTES
            && matches!(st.items.front(), Some(TxItem::Data { .. }))
        {
            let Some(TxItem::Data { buf, frames }) =
                st.items.pop_front()
            else {
                unreachable!("front() was Data");
            };
            bytes += buf.len();
            self.inflight.push((buf, frames));
        }
        if !self.inflight.is_empty() {
            return Gathered::Data;
        }
        if st.shutdown {
            return Gathered::Empty { shutdown: true };
        }
        st.parked = true;
        Gathered::Empty { shutdown: false }
    }

    /// Detach and drop the current socket.  `update_fd(-1)` happens
    /// *before* the close so a concurrent re-arm can never touch a
    /// recycled fd.
    fn drop_stream(&mut self, core: &IoCore) {
        let _ = core.update_fd(self.token(), -1);
        self.stream = None;
        self.head_written = 0; // resend the head buffer in full
    }

    /// Chaos cut / rebind handoff: sever the connection at this
    /// point in the stream — drain it in order (FIN, then wait for
    /// the receiver's close), and let the normal path reconnect.
    fn sever(&mut self, core: &IoCore) {
        let _ = core.update_fd(self.token(), -1);
        self.head_written = 0;
        if let Some(stream) = self.stream.take() {
            drain_connection(stream);
        }
    }

    /// Logical targets: notice a table version bump, re-resolve, and
    /// when the endpoint moved, drain the old connection **in
    /// order** before pointing at the new one.  Fixed targets never
    /// rebind.
    fn refresh(&mut self, core: &IoCore) -> Result<()> {
        let SenderTarget::Logical { table, flake_id } = &*self.target
        else {
            return Ok(());
        };
        if table.version() == self.seen_version
            && self.endpoint.is_some()
        {
            return Ok(());
        }
        let (version, endpoint) = table
            .resolve_tcp_versioned(flake_id)
            .ok_or_else(|| {
                FloeError::Channel(format!(
                    "tcp: flake '{flake_id}' has no published tcp \
                     endpoint"
                ))
            })?;
        self.seen_version = version;
        if self.endpoint.as_deref() != Some(endpoint.as_str()) {
            crate::log_debug!(
                "tcp: rebinding to {endpoint} (flake '{flake_id}' \
                 moved)"
            );
            if self.endpoint.is_some() {
                // A genuine rebind (not the first resolve).
                crate::telemetry::ctr_tcp_rebinds().inc();
                crate::telemetry::tracelog()
                    .instant("rebind", flake_id, &endpoint);
            }
            self.sever(core);
            self.endpoint = Some(endpoint);
        }
        Ok(())
    }

    /// Establish a connection to the resolved endpoint and attach
    /// its fd to the slot.  The connect itself blocks — acceptable
    /// on an I/O worker, like every other slow path here.
    fn reconnect(&mut self, core: &IoCore) -> Result<()> {
        let Some(endpoint) = self.endpoint.clone() else {
            return Err(FloeError::Channel(
                "tcp: endpoint unresolved".to_string(),
            ));
        };
        let stream = TcpStream::connect(&endpoint).map_err(|e| {
            FloeError::Channel(format!(
                "tcp reconnect to {endpoint}: {e}"
            ))
        })?;
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true).map_err(|e| {
            FloeError::Channel(format!("tcp: set_nonblocking: {e}"))
        })?;
        core.update_fd(self.token(), source_fd(&stream))?;
        self.stream = Some(stream);
        self.head_written = 0;
        Ok(())
    }

    /// One vectored flush of the in-flight window.
    fn flush_inflight(&mut self) -> FlushOutcome {
        let (res, coalesced) = {
            let head = self.head_written;
            let slices: Vec<IoSlice<'_>> = self
                .inflight
                .iter()
                .enumerate()
                .map(|(i, (buf, _))| {
                    if i == 0 {
                        IoSlice::new(&buf[head..])
                    } else {
                        IoSlice::new(&buf[..])
                    }
                })
                .collect();
            let coalesced = slices.len() > 1;
            let stream =
                self.stream.as_mut().expect("flush: connected");
            (stream.write_vectored(&slices), coalesced)
        };
        match res {
            Ok(0) => {
                FlushOutcome::Broken("wrote 0 bytes".to_string())
            }
            Ok(n) => {
                if crate::telemetry::enabled() {
                    crate::telemetry::hist_tcp_egress_flush()
                        .record(n as u64);
                    if coalesced {
                        crate::telemetry::ctr_tcp_egress_coalesced()
                            .inc();
                    }
                }
                if let Some(t0) = self.stall_since.take() {
                    if crate::telemetry::enabled() {
                        crate::telemetry::hist_tcp_egress_stall()
                            .record(t0.elapsed().as_nanos() as u64);
                    }
                }
                self.consume(n);
                self.last_write = Instant::now();
                self.attempt = 0;
                self.episode_deadline = None;
                FlushOutcome::Progress
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                FlushOutcome::Blocked
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                FlushOutcome::Retry
            }
            Err(e) => FlushOutcome::Broken(e.to_string()),
        }
    }

    /// Advance the in-flight window by `n` written bytes: fully
    /// written buffers are recycled to the producer pool and their
    /// bytes / frames counted; a partial head keeps its offset.
    fn consume(&mut self, mut n: usize) {
        let mut done: Vec<(Vec<u8>, u64)> = Vec::new();
        while n > 0 {
            let remaining =
                self.inflight[0].0.len() - self.head_written;
            if n >= remaining {
                n -= remaining;
                self.head_written = 0;
                done.push(self.inflight.remove(0));
            } else {
                self.head_written += n;
                n = 0;
            }
        }
        if done.is_empty() {
            return;
        }
        let count = done.len() as u64;
        let mut bytes = 0u64;
        let mut frames = 0u64;
        {
            let mut st = self
                .shared
                .state
                .lock()
                .expect("tcp sender poisoned");
            for (buf, f) in done {
                st.queued_bytes =
                    st.queued_bytes.saturating_sub(buf.len());
                bytes += buf.len() as u64;
                frames += f;
                recycle_buf(&mut st, buf);
            }
        }
        egress_depth_sub(count);
        self.shared.space.notify_all();
        if crate::telemetry::enabled() {
            crate::telemetry::ctr_tcp_tx_bytes().add(bytes);
            crate::telemetry::ctr_tcp_tx_frames().add(frames);
        }
    }

    /// The kernel buffer is full.  Arm the stall clock on the first
    /// block (plus a timer backstop — a wedged peer may never
    /// produce another writability event) and declare the
    /// connection broken once the stall bound expires.
    fn on_blocked(&mut self, core: &IoCore) -> Serve {
        let limit = write_stall_timeout();
        match self.stall_since {
            None => {
                self.stall_since = Some(Instant::now());
                if let Some(limit) = limit {
                    core.kick_in(self.token(), limit);
                }
                Serve::Continue
            }
            Some(t0) => match limit {
                Some(limit) if t0.elapsed() >= limit => {
                    if crate::telemetry::enabled() {
                        crate::telemetry::hist_tcp_egress_stall()
                            .record(t0.elapsed().as_nanos() as u64);
                    }
                    self.stall_since = None;
                    self.drop_stream(core);
                    let e = FloeError::Channel(format!(
                        "tcp send to {}: write stalled for \
                         {limit:?}",
                        self.endpoint
                            .as_deref()
                            .unwrap_or("<unresolved>")
                    ));
                    self.retry_or_give_up(core, e)
                }
                _ => Serve::Continue,
            },
        }
    }

    /// One failure in the current episode: give up (bounded attempts
    /// for fixed targets; the repair-bridging [`LOGICAL_SEND_DEADLINE`]
    /// wall clock for logical ones — wide enough to cover a
    /// `ReplaceFailed` repair, with the re-resolve between attempts
    /// picking up the replacement's endpoint) or schedule a jittered
    /// backoff retry via a poll timer — no worker ever sleeps.
    fn retry_or_give_up(
        &mut self,
        core: &IoCore,
        err: FloeError,
    ) -> Serve {
        self.last_err = err.to_string();
        self.attempt += 1;
        if self.episode_deadline.is_none() {
            if let SenderTarget::Logical { .. } = &*self.target {
                self.episode_deadline =
                    Some(Instant::now() + LOGICAL_SEND_DEADLINE);
            }
        }
        let give_up = match self.episode_deadline {
            Some(d) => Instant::now() >= d,
            None => self.attempt >= SEND_ATTEMPTS,
        };
        if give_up {
            // A logical sink still unreachable after the full
            // repair-bridging deadline is a suspected partition:
            // surface it to the failure detector (the lease path
            // cannot see a sender-side stall on its own).
            if let SenderTarget::Logical { flake_id, .. } =
                &*self.target
            {
                crate::coordinator::report_endpoint_stall(
                    flake_id,
                    &format!(
                        "send deadline expired after {} attempts: {}",
                        self.attempt, self.last_err
                    ),
                );
            }
            self.fail_pending();
            return Serve::Close;
        }
        crate::telemetry::ctr_tcp_reconnects().inc();
        self.seen_version = 0; // force a fresh resolve next attempt
        let delay = retry_backoff(self.attempt, &mut self.jitter);
        self.backoff_until = Some(Instant::now() + delay);
        core.kick_in(self.token(), delay);
        Serve::Park
    }

    /// Retries exhausted: drop everything queued, mark the pipeline
    /// broken (the next `send_all` surfaces the error once and
    /// respawns) and free any blocked producers.  Delivery stays
    /// at-least-once *with error surfacing*: batches pending at
    /// give-up are reported lost to the producer, exactly as the old
    /// synchronous path errored the batch it was carrying.
    fn fail_pending(&mut self) {
        let mut dropped = self.inflight.len() as u64;
        self.inflight.clear();
        self.head_written = 0;
        let err = format!(
            "tcp: giving up after {} attempts: {}",
            self.attempt, self.last_err
        );
        crate::log_warn!("{err}");
        {
            let mut st = self
                .shared
                .state
                .lock()
                .expect("tcp sender poisoned");
            for item in st.items.drain(..) {
                if let TxItem::Data { .. } = item {
                    dropped += 1;
                }
            }
            st.queued_bytes = 0;
            st.parked = false;
            st.live = false;
            st.broken = Some(err);
        }
        egress_depth_sub(dropped);
        self.shared.space.notify_all();
    }
}

impl Drop for TxConn {
    /// Runs when the slot retires (give-up, shutdown drain, or group
    /// close).  Clean the shared state only if it still belongs to
    /// this spawn generation — a respawned pipeline's queue must not
    /// be clobbered by its predecessor's teardown.
    fn drop(&mut self) {
        let mut dropped = self.inflight.len() as u64;
        self.inflight.clear();
        if let Ok(mut st) = self.shared.state.lock() {
            if st.epoch == self.epoch {
                for item in st.items.drain(..) {
                    if let TxItem::Data { .. } = item {
                        dropped += 1;
                    }
                }
                st.queued_bytes = 0;
                st.parked = false;
                st.live = false;
            }
        }
        egress_depth_sub(dropped);
        self.shared.space.notify_all();
    }
}

/// In-order rebind handshake: stop sending (FIN via write-half
/// shutdown), then wait — bounded — until the receiver has decoded
/// everything and closed its end (EOF).  Only after that may the
/// caller write to the *new* endpoint, so bytes on the old connection
/// can never be overtaken by bytes on the new one.
fn drain_connection(mut stream: TcpStream) {
    // Egress sockets run nonblocking on the I/O core; the bounded
    // read loop below relies on read timeouts, which nonblocking
    // sockets ignore — restore blocking mode first.
    let _ = stream.set_nonblocking(false);
    let _ = stream.shutdown(Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let deadline = Instant::now() + REBIND_DRAIN_TIMEOUT;
    let mut buf = [0u8; 256];
    while Instant::now() < deadline {
        match stream.read(&mut buf) {
            Ok(0) => return, // receiver finished and closed
            Ok(_) => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return,
        }
    }
    // The receiver did not finish inside the drain window (e.g. its
    // sink queues are saturated).  Proceeding to the new endpoint can
    // reorder this producer's stream relative to the undrained tail —
    // surface it rather than fail silently; the old frames themselves
    // still deliver through the lingering receiver.
    crate::log_warn!(
        "tcp: rebind drain timed out after {:?}; per-producer order \
         across the rebind is not guaranteed for this sender",
        REBIND_DRAIN_TIMEOUT
    );
}

/// Exponential backoff with equal jitter: `base/2 + uniform(0 ..=
/// base/2)` where `base` doubles per attempt up to
/// [`SEND_BACKOFF_CAP`].  Unjittered, every sender cut by the same
/// event retries in lockstep and hammers the recovering sink in
/// synchronized waves; the per-sender seeded stream keeps runs
/// reproducible under a fixed chaos seed.
fn retry_backoff(attempt: usize, jitter: &mut Rng) -> Duration {
    let cap = SEND_BACKOFF_CAP.as_millis() as u64;
    let base = (1u64 << attempt.min(10)).min(cap);
    let half = base / 2;
    Duration::from_millis(half + jitter.below(base - half + 1))
}

/// Probe a cached idle connection for a silent peer close.  Egress
/// sockets are already nonblocking, so a plain read suffices: it
/// returns `WouldBlock` on a healthy idle socket, `Ok(0)` after a
/// FIN and an error after a reset.  (Receivers never send
/// application bytes, so `Ok(n)` only occurs on protocol abuse —
/// treated as healthy and left to the write path.)
fn stream_stale(s: &mut TcpStream) -> bool {
    let mut probe = [0u8; 16];
    match s.read(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    }
}

impl Transport for TcpSender {
    fn send(&self, msg: Message) -> Result<()> {
        self.send_all(std::slice::from_ref(&msg))
    }

    /// Frame the whole batch into one pooled buffer — it travels the
    /// egress queue as one unit and flushes with (at most) a single
    /// vectored syscall.
    fn send_batch(&self, msgs: Vec<Message>) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        self.send_all(&msgs)
    }

    fn describe(&self) -> String {
        match &*self.target {
            SenderTarget::Fixed(ep) => {
                format!("tcp:{ep}#{}", self.port_name)
            }
            SenderTarget::Logical { flake_id, .. } => format!(
                "tcp:{}",
                EndpointAddr::new(flake_id.clone(), self.port_name.clone())
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_pair() -> (TcpReceiver, Arc<ShardedQueue<Message>>, String) {
        let q = Arc::new(ShardedQueue::with_default_shards(4096));
        let mut ports = HashMap::new();
        ports.insert("in".to_string(), Arc::clone(&q));
        let rx = TcpReceiver::start(0, ports).unwrap();
        let ep = rx.endpoint();
        (rx, q, ep)
    }

    fn port_map(
        q: &Arc<ShardedQueue<Message>>,
    ) -> HashMap<String, Arc<ShardedQueue<Message>>> {
        let mut m = HashMap::new();
        m.insert("in".to_string(), Arc::clone(q));
        m
    }

    #[test]
    fn roundtrip_over_tcp() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("one").with_key("k")).unwrap();
        tx.send(Message::f32s(vec![1.0, 2.0, 3.0])).unwrap();
        let a = q.pop().unwrap();
        assert_eq!(a.as_text(), Some("one"));
        assert_eq!(a.key.as_deref(), Some("k"));
        let b = q.pop().unwrap();
        assert_eq!(b.as_f32s(), Some(&[1.0f32, 2.0, 3.0][..]));
        rx.shutdown();
    }

    /// Wire compatibility: a legacy frame (no [`CHECKSUM_FLAG`], no
    /// CRC trailer) hand-built over a raw socket still decodes and
    /// delivers — mixed-version senders interoperate.
    #[test]
    fn legacy_unchecksummed_frame_still_decodes() {
        let (mut rx, q, ep) = start_pair();
        let body = Message::text("old-wire").encode();
        let mut frame = Vec::new();
        frame.extend_from_slice(&[0u8; 4]);
        frame.extend_from_slice(&(2u16).to_le_bytes()); // no flag
        frame.extend_from_slice(b"in");
        frame.extend_from_slice(&body);
        let total = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&total.to_le_bytes());
        let mut s = TcpStream::connect(&ep).unwrap();
        s.write_all(&frame).unwrap();
        s.flush().unwrap();
        let m = q.pop().unwrap();
        assert_eq!(m.as_text(), Some("old-wire"));
        rx.shutdown();
    }

    /// A corrupted checksummed frame is detected (counter bumped),
    /// dropped before any byte is interpreted, and the connection is
    /// closed; frames decoded before the corruption still deliver and
    /// a fresh connection keeps working.
    #[test]
    fn corrupt_frame_detected_and_dropped() {
        let (mut rx, q, ep) = start_pair();
        let before =
            crate::telemetry::ctr_tcp_corrupt_frames().get();
        let mut buf = Vec::new();
        TcpSender::frame_into("in", &Message::text("good"), &mut buf);
        let cut = buf.len();
        TcpSender::frame_into("in", &Message::text("evil"), &mut buf);
        // Flip a payload byte of the second frame, past its prefix.
        buf[cut + 4 + 2] ^= 0xFF;
        let mut s = TcpStream::connect(&ep).unwrap();
        s.write_all(&buf).unwrap();
        s.flush().unwrap();
        // The clean prefix frame delivers...
        assert_eq!(q.pop().unwrap().as_text(), Some("good"));
        // ...the corrupt one never does, and is counted.
        let deadline = Instant::now() + Duration::from_secs(5);
        while crate::telemetry::ctr_tcp_corrupt_frames().get()
            == before
        {
            assert!(Instant::now() < deadline, "corruption uncounted");
            thread::sleep(Duration::from_millis(2));
        }
        assert!(q.is_empty(), "corrupt frame was delivered");
        // The receiver cut the connection; a new one still serves.
        let tx = TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("after")).unwrap();
        assert_eq!(q.pop().unwrap().as_text(), Some("after"));
        rx.shutdown();
    }

    #[test]
    fn many_messages_in_order() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        for i in 0..500 {
            tx.send(Message::text(format!("m{i}"))).unwrap();
        }
        for i in 0..500 {
            assert_eq!(q.pop().unwrap().as_text(), Some(&*format!("m{i}")));
        }
        rx.shutdown();
    }

    #[test]
    fn batch_send_arrives_in_order() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        for chunk in 0..10 {
            let batch: Vec<Message> = (0..100)
                .map(|i| Message::text(format!("b{}", chunk * 100 + i)))
                .collect();
            tx.send_batch(batch).unwrap();
        }
        for i in 0..1000 {
            assert_eq!(q.pop().unwrap().as_text(), Some(&*format!("b{i}")));
        }
        rx.shutdown();
    }

    #[test]
    fn unknown_port_dropped_known_delivered() {
        let (mut rx, q, ep) = start_pair();
        let bad = TcpSender::connect(&ep, "nope").unwrap();
        bad.send(Message::text("lost")).unwrap();
        let good = TcpSender::connect(&ep, "in").unwrap();
        good.send(Message::text("kept")).unwrap();
        assert_eq!(q.pop().unwrap().as_text(), Some("kept"));
        assert!(q.is_empty());
        rx.shutdown();
    }

    #[test]
    fn concurrent_senders() {
        let (mut rx, q, ep) = start_pair();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let ep = ep.clone();
                thread::spawn(move || {
                    let tx = TcpSender::connect(&ep, "in").unwrap();
                    for i in 0..100 {
                        tx.send(Message::text(format!("{t}-{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..400 {
            got.push(q.pop().unwrap().as_text().unwrap().to_string());
        }
        got.sort();
        got.dedup();
        assert_eq!(got.len(), 400);
        rx.shutdown();
    }

    /// Regression (reconnect hardening): a listener that drops its
    /// first accepted connection must not surface as a hard error —
    /// the sender retries through reconnect with bounded attempts.
    #[test]
    fn sender_retries_through_dropped_first_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        let q = Arc::new(ShardedQueue::with_default_shards(4096));
        let route = RxRoute::Direct(port_map(&q));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = thread::spawn(move || {
            // First connection: accepted and dropped on the floor.
            let (first, _) = listener.accept().unwrap();
            drop(first);
            // Second connection: served properly.
            let (stream, _) = listener.accept().unwrap();
            let _ = serve_blocking(stream, &route, &stop2);
        });

        let tx = TcpSender::connect(&ep, "in").unwrap();
        // The first write may land in the kernel buffer before the
        // reset arrives (inherent TCP) — its outcome is not asserted.
        let _ = tx.send(Message::text("first"));
        thread::sleep(Duration::from_millis(50));
        // These must all succeed via the bounded reconnect path.
        for i in 0..4 {
            tx.send(Message::text(format!("r{i}"))).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut got: Vec<String> = Vec::new();
        while got.iter().filter(|t| t.starts_with('r')).count() < 4 {
            assert!(
                Instant::now() < deadline,
                "retried messages never arrived: {got:?}"
            );
            if let Some(m) = q.try_pop() {
                got.push(m.as_text().unwrap().to_string());
            } else {
                thread::sleep(Duration::from_millis(2));
            }
        }
        let retried: Vec<&String> =
            got.iter().filter(|t| t.starts_with('r')).collect();
        assert_eq!(retried, vec!["r0", "r1", "r2", "r3"], "{got:?}");
        stop.store(true, Ordering::SeqCst);
        drop(tx); // closes the connection; serve_blocking returns
        server.join().unwrap();
    }

    /// A sender that exhausts its attempts (nobody listening) reports
    /// a channel error instead of hanging.  On the pipelined path the
    /// failure is asynchronous: the TxConn burns its bounded attempts
    /// in the background and a *later* send surfaces the error.
    #[test]
    fn sender_gives_up_after_bounded_attempts() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        // Closing the listener resets the backlogged connection and
        // refuses every reconnect, so the pipeline's retries are
        // guaranteed to exhaust.
        drop(listener);
        let deadline = Instant::now() + Duration::from_secs(30);
        let err = loop {
            assert!(
                Instant::now() < deadline,
                "sender never surfaced the give-up error"
            );
            match tx.send(Message::text("x")) {
                Ok(()) => thread::sleep(Duration::from_millis(10)),
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("giving up"), "{err}");
    }

    #[test]
    fn logical_roundtrip_and_rebind_preserve_order() {
        let table = EndpointTable::new();
        let q1 = Arc::new(ShardedQueue::with_default_shards(4096));
        let mut rx1 = TcpReceiver::start_logical(
            0,
            "sink",
            Arc::clone(&table),
        )
        .unwrap();
        let token =
            table.publish("sink", port_map(&q1), Some(rx1.endpoint()));
        let _ = token;

        let tx = TcpSender::logical(
            Arc::clone(&table),
            &EndpointAddr::new("sink", "in"),
        )
        .unwrap();
        for i in 0..50 {
            tx.send(Message::text(format!("a{i:03}"))).unwrap();
        }
        // Wait for delivery, then "relocate": new queue, new receiver,
        // republish under the same logical id.
        let deadline = Instant::now() + Duration::from_secs(5);
        while q1.len() < 50 {
            assert!(Instant::now() < deadline, "first batch missing");
            thread::sleep(Duration::from_millis(2));
        }
        let q2 = Arc::new(ShardedQueue::with_default_shards(4096));
        let mut rx2 = TcpReceiver::start_logical(
            0,
            "sink",
            Arc::clone(&table),
        )
        .unwrap();
        assert_ne!(rx1.endpoint(), rx2.endpoint());
        table.publish("sink", port_map(&q2), Some(rx2.endpoint()));
        for i in 50..100 {
            tx.send(Message::text(format!("a{i:03}"))).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while q2.len() < 50 {
            assert!(
                Instant::now() < deadline,
                "post-rebind batch missing (got {})",
                q2.len()
            );
            thread::sleep(Duration::from_millis(2));
        }
        // Zero loss, and order preserved within each side of the cut.
        let mut texts = Vec::new();
        while let Some(m) = q1.try_pop() {
            texts.push(m.as_text().unwrap().to_string());
        }
        while let Some(m) = q2.try_pop() {
            texts.push(m.as_text().unwrap().to_string());
        }
        let want: Vec<String> =
            (0..100).map(|i| format!("a{i:03}")).collect();
        assert_eq!(texts, want);
        rx1.shutdown();
        rx2.shutdown();
    }

    /// Logical mode: an unknown port on a *live* flake is permanent —
    /// the batch drops with a warning and the connection keeps
    /// serving other ports (it must not stall retrying or die).
    #[test]
    fn logical_unknown_port_drops_and_connection_survives() {
        let table = EndpointTable::new();
        let q = Arc::new(ShardedQueue::with_default_shards(64));
        let mut rx = TcpReceiver::start_logical(
            0,
            "sink",
            Arc::clone(&table),
        )
        .unwrap();
        let ep = rx.endpoint();
        table.publish("sink", port_map(&q), Some(ep.clone()));
        let tx = TcpSender::connect(&ep, "nope").unwrap();
        tx.send(Message::text("lost")).unwrap();
        let good = TcpSender::connect(&ep, "in").unwrap();
        good.send(Message::text("kept")).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some(m) = q.try_pop() {
                assert_eq!(m.as_text(), Some("kept"));
                break;
            }
            assert!(Instant::now() < deadline, "good port starved");
            thread::sleep(Duration::from_millis(2));
        }
        assert!(q.is_empty());
        rx.shutdown();
    }

    /// Regression (PR 5 follow-up): a lingering receiver armed with
    /// idle teardown stays up while a sender is still connected, and
    /// tears itself down — closing the listening socket — once the
    /// last sender disconnects and the idle window elapses.
    #[test]
    fn idle_teardown_waits_for_last_sender_then_closes() {
        let (mut rx, q, ep) = start_pair();
        let tx = TcpSender::connect(&ep, "in").unwrap();
        tx.send(Message::text("x")).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while q.is_empty() {
            assert!(Instant::now() < deadline, "delivery missing");
            thread::sleep(Duration::from_millis(2));
        }
        rx.enable_idle_teardown(Duration::from_millis(100));
        // A live connection pins the receiver past the idle window.
        thread::sleep(Duration::from_millis(300));
        assert!(!rx.is_torn_down(), "torn down under a live sender");
        tx.send(Message::text("still-up")).unwrap();
        drop(tx); // last sender rebinds away
        let deadline = Instant::now() + Duration::from_secs(5);
        while !rx.is_torn_down() {
            assert!(
                Instant::now() < deadline,
                "idle receiver never tore down"
            );
            thread::sleep(Duration::from_millis(5));
        }
        // The listener is gone: fresh connections are refused.
        assert!(TcpStream::connect(&ep).is_err());
        rx.shutdown(); // joins the already-exited accept thread
    }

    /// Idle teardown on a receiver that never sees a connection fires
    /// one idle window after it is armed — not instantly.
    #[test]
    fn idle_teardown_clock_starts_at_enable() {
        let (mut rx, _q, _ep) = start_pair();
        thread::sleep(Duration::from_millis(150));
        rx.enable_idle_teardown(Duration::from_millis(100));
        assert!(!rx.is_torn_down(), "fired before the window");
        let deadline = Instant::now() + Duration::from_secs(5);
        while !rx.is_torn_down() {
            assert!(
                Instant::now() < deadline,
                "armed idle receiver never tore down"
            );
            thread::sleep(Duration::from_millis(5));
        }
        rx.shutdown();
    }

    /// Regression (backlog severing): the idle teardown must drain
    /// the kernel backlog before dropping the listener, so a sender
    /// whose connect raced the teardown deadline is served, not
    /// severed.  The checkable invariant uses the FIN/EOF drain
    /// handshake: a sender that wrote its frame, shut down its write
    /// half, and then read a clean EOF was *served* — the receiver
    /// decodes and delivers everything before closing — so every
    /// EOF-confirmed message must be in the queue.  A reset instead
    /// of EOF means the connection lost the race outright (the
    /// sender sees the error and, in production, rebinds) and makes
    /// no delivery claim.
    #[test]
    fn idle_teardown_drains_backlog_at_deadline() {
        for round in 0..10 {
            let (mut rx, q, ep) = start_pair();
            rx.enable_idle_teardown(Duration::from_millis(10));
            let mut confirmed = 0usize;
            for i in 0..40 {
                // Jittered pacing so some connects land right on the
                // 10ms deadline (an accepted connection resets the
                // idle clock at close, re-arming the race each time).
                thread::sleep(Duration::from_millis((i % 4) * 5));
                let Ok(mut s) = TcpStream::connect(&ep) else {
                    break; // torn down: the race is over
                };
                let msg = Message::text(format!("r{round}-c{i}"));
                let mut buf = Vec::new();
                TcpSender::frame_into("in", &msg, &mut buf);
                if s.write_all(&buf).is_err() {
                    continue; // severed mid-write: no claim
                }
                let _ = s.shutdown(Shutdown::Write);
                let _ = s.set_read_timeout(Some(
                    Duration::from_secs(5),
                ));
                let mut b = [0u8; 8];
                if matches!(s.read(&mut b), Ok(0)) {
                    confirmed += 1; // clean EOF: it was served
                }
            }
            let mut got = 0usize;
            while q.try_pop().is_some() {
                got += 1;
            }
            assert!(
                got >= confirmed,
                "round {round}: {} EOF-confirmed message(s) lost \
                 ({got} delivered, {confirmed} confirmed)",
                confirmed - got
            );
            rx.shutdown();
        }
    }

    /// Logical delivery follows a republication that happens while the
    /// receiver's sink queue is closed (the relocation handoff window).
    #[test]
    fn logical_delivery_retries_across_republication() {
        let table = EndpointTable::new();
        let q1 = Arc::new(ShardedQueue::with_default_shards(4096));
        let mut rx = TcpReceiver::start_logical(
            0,
            "sink",
            Arc::clone(&table),
        )
        .unwrap();
        table.publish("sink", port_map(&q1), Some(rx.endpoint()));
        let tx = TcpSender::logical(
            Arc::clone(&table),
            &EndpointAddr::new("sink", "in"),
        )
        .unwrap();
        // Close the published queue (handoff capture does this), then
        // republish a fresh queue shortly after — the in-flight
        // delivery must retry into the replacement, not drop.
        q1.close();
        tx.send(Message::text("survivor")).unwrap();
        thread::sleep(Duration::from_millis(30));
        let q2 = Arc::new(ShardedQueue::with_default_shards(4096));
        table.publish("sink", port_map(&q2), Some(rx.endpoint()));
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some(m) = q2.try_pop() {
                assert_eq!(m.as_text(), Some("survivor"));
                break;
            }
            assert!(
                Instant::now() < deadline,
                "delivery dropped during the republication window"
            );
            thread::sleep(Duration::from_millis(2));
        }
        rx.shutdown();
    }
}
