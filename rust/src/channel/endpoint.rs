//! Logical endpoint addressing: the location-transparency layer of the
//! data plane.
//!
//! Every flake input port has a stable **logical address**
//! `floe://<flake-id>/<port>` ([`EndpointAddr`]).  Senders never hold a
//! socket or queue handle directly; they hold the logical address plus
//! an [`EndpointTable`] and resolve logical → physical on demand.  The
//! table is **versioned**: every publication bumps a global version
//! counter, and resolvers cache their last resolution keyed by that
//! version, so the steady-state cost of location transparency is one
//! atomic load per send.
//!
//! This is what makes flakes relocatable regardless of ingress
//! transport: a relocation republishes the moved flake's endpoints at
//! the new container (same logical address, new physical queues / TCP
//! endpoint), the version bumps, and every sender — in-process
//! [`EndpointTransport`]s, remote [`crate::channel::TcpSender`]s in
//! logical mode, and the table-resolving delivery path of
//! [`crate::channel::TcpReceiver`] — re-resolves and carries on.  No
//! sender ever needs to be told where a flake went.  Pipelined TCP
//! senders re-resolve from their I/O-core state machines via
//! [`EndpointTable::resolve_tcp_versioned`], which pairs the endpoint
//! with the version to cache it under in the race-safe order.
//!
//! Publication is token-guarded: [`EndpointTable::publish`] returns a
//! token, and [`EndpointTable::unpublish_if`] removes the entry only
//! when the token still matches.  A relocation replaces the entry (new
//! token), so the displaced husk's shutdown cannot tear down the
//! replacement's publication.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::channel::{ShardedQueue, Transport};
use crate::error::{FloeError, Result};
use crate::message::Message;

/// URI scheme of logical endpoint addresses.
pub const ENDPOINT_SCHEME: &str = "floe://";

/// Logical address of one flake input port: `floe://<flake-id>/<port>`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EndpointAddr {
    pub flake_id: String,
    pub port: String,
}

impl EndpointAddr {
    pub fn new(
        flake_id: impl Into<String>,
        port: impl Into<String>,
    ) -> EndpointAddr {
        EndpointAddr { flake_id: flake_id.into(), port: port.into() }
    }

    /// Parse a `floe://<flake-id>/<port>` URI.
    pub fn parse(uri: &str) -> Result<EndpointAddr> {
        let rest = uri.strip_prefix(ENDPOINT_SCHEME).ok_or_else(|| {
            FloeError::Parse(format!(
                "endpoint: '{uri}' does not start with {ENDPOINT_SCHEME}"
            ))
        })?;
        let (flake_id, port) = rest.split_once('/').ok_or_else(|| {
            FloeError::Parse(format!(
                "endpoint: '{uri}' is missing the /<port> part"
            ))
        })?;
        if flake_id.is_empty() || port.is_empty() || port.contains('/') {
            return Err(FloeError::Parse(format!(
                "endpoint: malformed address '{uri}'"
            )));
        }
        Ok(EndpointAddr::new(flake_id, port))
    }
}

impl fmt::Display for EndpointAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{ENDPOINT_SCHEME}{}/{}", self.flake_id, self.port)
    }
}

/// Physical resolution of one flake: its input-port queues and, when a
/// TCP receiver serves it, the `host:port` remote ingress endpoint.
struct FlakeEndpoints {
    token: u64,
    ports: HashMap<String, Arc<ShardedQueue<Message>>>,
    tcp: Option<String>,
}

/// The versioned logical → physical routing table (see module docs).
///
/// One authoritative table per running dataflow, owned by the
/// coordinator's `Topology` and shared (`Arc`) with every transport
/// that resolves through it.
pub struct EndpointTable {
    version: AtomicU64,
    tokens: AtomicU64,
    entries: RwLock<HashMap<String, FlakeEndpoints>>,
}

impl EndpointTable {
    pub fn new() -> Arc<EndpointTable> {
        Arc::new(EndpointTable {
            version: AtomicU64::new(1),
            tokens: AtomicU64::new(0),
            entries: RwLock::new(HashMap::new()),
        })
    }

    /// Current table version.  Bumped by every publication change;
    /// resolvers cache per version.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    fn bump(&self) -> u64 {
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Publish (or replace) a flake's endpoints.  Returns the
    /// publication token for [`EndpointTable::unpublish_if`].
    ///
    /// The entry is committed *before* the version bump (like every
    /// mutation here): a resolver that reads the bumped version is
    /// guaranteed to resolve the new entry, so it can never cache a
    /// stale resolution under the new version and miss the rebind.
    pub fn publish(
        &self,
        flake_id: &str,
        ports: HashMap<String, Arc<ShardedQueue<Message>>>,
        tcp: Option<String>,
    ) -> u64 {
        let token = self.tokens.fetch_add(1, Ordering::AcqRel) + 1;
        self.entries
            .write()
            .expect("endpoint table poisoned")
            .insert(
                flake_id.to_string(),
                FlakeEndpoints { token, ports, tcp },
            );
        self.bump();
        token
    }

    /// Record the TCP ingress endpoint of an already-published flake.
    /// Guarded by the publication token so a displaced incarnation
    /// cannot overwrite its replacement's endpoint.
    pub fn set_tcp(
        &self,
        flake_id: &str,
        token: u64,
        endpoint: &str,
    ) -> Result<()> {
        let mut entries =
            self.entries.write().expect("endpoint table poisoned");
        let e = entries.get_mut(flake_id).ok_or_else(|| {
            FloeError::Channel(format!(
                "endpoint: '{flake_id}' is not published"
            ))
        })?;
        if e.token != token {
            return Err(FloeError::Channel(format!(
                "endpoint: stale publication token for '{flake_id}'"
            )));
        }
        e.tcp = Some(endpoint.to_string());
        drop(entries);
        self.bump();
        Ok(())
    }

    /// Remove a flake's entry *iff* `token` still matches the current
    /// publication (see module docs).  Returns whether it was removed.
    pub fn unpublish_if(&self, flake_id: &str, token: u64) -> bool {
        let mut entries =
            self.entries.write().expect("endpoint table poisoned");
        let matches = entries
            .get(flake_id)
            .map(|e| e.token == token)
            .unwrap_or(false);
        if matches {
            entries.remove(flake_id);
            drop(entries);
            self.bump();
        }
        matches
    }

    /// Resolve a logical port address to its current physical queue.
    pub fn resolve_queue(
        &self,
        flake_id: &str,
        port: &str,
    ) -> Option<Arc<ShardedQueue<Message>>> {
        self.entries
            .read()
            .expect("endpoint table poisoned")
            .get(flake_id)?
            .ports
            .get(port)
            .cloned()
    }

    /// Resolve a flake's current TCP ingress endpoint (`host:port`).
    pub fn resolve_tcp(&self, flake_id: &str) -> Option<String> {
        self.entries
            .read()
            .expect("endpoint table poisoned")
            .get(flake_id)?
            .tcp
            .clone()
    }

    /// Resolve a flake's TCP endpoint together with the version to
    /// cache it under.  The version is read *before* the entry, so a
    /// racing publish can only make the cached pairing stale (the
    /// next version check re-resolves), never let a resolver cache
    /// the *old* endpoint under the *new* version and miss a rebind.
    /// This is the lookup the pipelined egress path uses from its
    /// I/O-core state machines.
    pub fn resolve_tcp_versioned(
        &self,
        flake_id: &str,
    ) -> Option<(u64, String)> {
        let version = self.version();
        Some((version, self.resolve_tcp(flake_id)?))
    }

    /// Whether a flake is currently published at all — lets delivery
    /// paths distinguish an unknown *port* on a live flake (permanent:
    /// drop) from a flake that is gone (shutdown in progress).
    pub fn has_flake(&self, flake_id: &str) -> bool {
        self.entries
            .read()
            .expect("endpoint table poisoned")
            .contains_key(flake_id)
    }

    /// Number of published flakes.
    pub fn published(&self) -> usize {
        self.entries.read().expect("endpoint table poisoned").len()
    }

    /// Every published logical address, sorted (stats / diagnostics).
    pub fn addresses(&self) -> Vec<String> {
        let entries =
            self.entries.read().expect("endpoint table poisoned");
        let mut out: Vec<String> = entries
            .iter()
            .flat_map(|(id, e)| {
                e.ports
                    .keys()
                    .map(|p| EndpointAddr::new(id, p).to_string())
                    .collect::<Vec<_>>()
            })
            .collect();
        out.sort();
        out
    }
}

/// How long a blocking send waits out a closed-but-published sink
/// queue: wide enough to bridge a `ReplaceFailed` repair of a crashed
/// sink (lease expiry + respawn + checkpoint restore + republish).
const REPAIR_WAIT: Duration = Duration::from_secs(5);
/// Pause between re-resolutions while waiting.
const REPAIR_BACKOFF: Duration = Duration::from_millis(2);

struct CachedSink {
    version: u64,
    queue: Option<Arc<ShardedQueue<Message>>>,
}

/// In-process transport addressed **logically**: resolves
/// `floe://<flake-id>/<port>` through the [`EndpointTable`] on every
/// version bump and pushes into whatever queue the table names today.
/// This is the standard edge transport wired by the coordinator and
/// the recomposition engine; after a relocation republishes the sink,
/// the next send lands in the replacement without rewiring.
///
/// Failure semantics: an *unpublished* endpoint is an immediate
/// channel error (the recompose engine rewires the upstream frontier
/// before it retires a sink, so a live edge only sees this on
/// misconfiguration).  A *closed* queue under a standing publication
/// is different — that is a crashed sink whose repair is in flight
/// (a crash closes the queues but deliberately leaves the publication
/// up), so blocking sends wait it out up to [`REPAIR_WAIT`],
/// re-resolving on every table version bump, and land in the
/// replacement once `ReplaceFailed` republishes it.
pub struct EndpointTransport {
    table: Arc<EndpointTable>,
    addr: EndpointAddr,
    label: String,
    cached: Mutex<CachedSink>,
}

impl EndpointTransport {
    pub fn new(
        table: Arc<EndpointTable>,
        addr: EndpointAddr,
        label: impl Into<String>,
    ) -> EndpointTransport {
        EndpointTransport {
            table,
            addr,
            label: label.into(),
            cached: Mutex::new(CachedSink { version: 0, queue: None }),
        }
    }

    /// The sink queue at the current table version (cached per
    /// version: steady state is one atomic load + one mutex lock).
    fn sink(&self) -> Result<Arc<ShardedQueue<Message>>> {
        let version = self.table.version();
        let mut cached =
            self.cached.lock().expect("endpoint cache poisoned");
        if cached.version != version || cached.queue.is_none() {
            cached.queue = self
                .table
                .resolve_queue(&self.addr.flake_id, &self.addr.port);
            cached.version = version;
        }
        cached.queue.clone().ok_or_else(|| {
            FloeError::Channel(format!(
                "{}: endpoint {} is not published",
                self.label, self.addr
            ))
        })
    }

    /// The sink queue, waiting out a closed-but-published one (a
    /// crashed sink mid-repair — see the type docs).  The closed check
    /// happens *before* the push because `push` consumes its message
    /// even when it fails; a close that races the push itself is a
    /// crash-instant loss, which checkpoint replay already bounds.
    fn live_sink(&self) -> Result<Arc<ShardedQueue<Message>>> {
        let mut deadline: Option<Instant> = None;
        loop {
            let q = self.sink()?;
            if !q.is_closed() {
                return Ok(q);
            }
            let d = *deadline
                .get_or_insert_with(|| Instant::now() + REPAIR_WAIT);
            if Instant::now() >= d {
                // A sink still closed after the whole repair window
                // is a suspected partition / wedged repair — surface
                // it to the failure detector before erroring out.
                crate::coordinator::report_endpoint_stall(
                    &self.addr.flake_id,
                    &format!(
                        "{}: no repair within {REPAIR_WAIT:?}",
                        self.label
                    ),
                );
                return Err(FloeError::Channel(format!(
                    "{} closed (no repair within {REPAIR_WAIT:?})",
                    self.label
                )));
            }
            thread::sleep(REPAIR_BACKOFF);
        }
    }
}

impl Transport for EndpointTransport {
    fn send(&self, msg: Message) -> Result<()> {
        self.live_sink()?.push(msg).map_err(|_| {
            FloeError::Channel(format!("{} closed", self.label))
        })
    }

    fn send_batch(&self, msgs: Vec<Message>) -> Result<()> {
        self.live_sink()?.push_batch(msgs).map_err(|_| {
            FloeError::Channel(format!("{} closed", self.label))
        })
    }

    fn try_send(&self, msg: Message) -> Result<bool> {
        let q = self.sink()?;
        match q.try_push(msg) {
            Ok(()) => Ok(true),
            Err(_) if q.is_closed() => Err(FloeError::Channel(format!(
                "{} closed",
                self.label
            ))),
            Err(_) => Ok(false),
        }
    }

    fn describe(&self) -> String {
        format!("endpoint:{} ({})", self.addr, self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue() -> Arc<ShardedQueue<Message>> {
        Arc::new(ShardedQueue::with_default_shards(64))
    }

    fn ports(
        q: &Arc<ShardedQueue<Message>>,
    ) -> HashMap<String, Arc<ShardedQueue<Message>>> {
        let mut m = HashMap::new();
        m.insert("in".to_string(), Arc::clone(q));
        m
    }

    #[test]
    fn addr_roundtrip_and_rejects_malformed() {
        let a = EndpointAddr::new("cnt", "in");
        assert_eq!(a.to_string(), "floe://cnt/in");
        assert_eq!(EndpointAddr::parse("floe://cnt/in").unwrap(), a);
        for bad in [
            "cnt/in",
            "floe://cnt",
            "floe:///in",
            "floe://cnt/",
            "floe://a/b/c",
        ] {
            assert!(EndpointAddr::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn publish_resolve_unpublish_bump_versions() {
        let t = EndpointTable::new();
        let v0 = t.version();
        let q = queue();
        let token = t.publish("a", ports(&q), None);
        assert!(t.version() > v0);
        assert!(Arc::ptr_eq(&t.resolve_queue("a", "in").unwrap(), &q));
        assert!(t.resolve_queue("a", "out").is_none());
        assert!(t.resolve_queue("b", "in").is_none());
        assert_eq!(t.resolve_tcp("a"), None);
        t.set_tcp("a", token, "127.0.0.1:9").unwrap();
        assert_eq!(t.resolve_tcp("a").as_deref(), Some("127.0.0.1:9"));
        assert_eq!(t.addresses(), vec!["floe://a/in".to_string()]);
        assert!(t.unpublish_if("a", token));
        assert!(t.resolve_queue("a", "in").is_none());
        assert_eq!(t.published(), 0);
    }

    #[test]
    fn stale_token_cannot_unpublish_or_set_tcp() {
        let t = EndpointTable::new();
        let q1 = queue();
        let old = t.publish("a", ports(&q1), None);
        let q2 = queue();
        let _new = t.publish("a", ports(&q2), None); // relocation
        assert!(!t.unpublish_if("a", old), "stale token removed entry");
        assert!(t.set_tcp("a", old, "127.0.0.1:9").is_err());
        assert!(Arc::ptr_eq(&t.resolve_queue("a", "in").unwrap(), &q2));
    }

    #[test]
    fn transport_follows_republication() {
        let t = EndpointTable::new();
        let q1 = queue();
        t.publish("a", ports(&q1), None);
        let tx = EndpointTransport::new(
            Arc::clone(&t),
            EndpointAddr::new("a", "in"),
            "edge",
        );
        tx.send(Message::text("one")).unwrap();
        assert_eq!(q1.pop().unwrap().as_text(), Some("one"));
        // Relocate: republish the same logical address at a new queue.
        let q2 = queue();
        t.publish("a", ports(&q2), None);
        tx.send_batch(vec![Message::text("two")]).unwrap();
        assert!(q1.is_empty(), "stale queue hit after republication");
        assert_eq!(q2.pop().unwrap().as_text(), Some("two"));
    }

    /// A crashed sink closes its queues but leaves its publication up;
    /// a blocking send must wait out that window and land in the
    /// replacement once the repair republishes the logical address.
    #[test]
    fn transport_waits_out_closed_queue_until_republish() {
        let t = EndpointTable::new();
        let q1 = queue();
        t.publish("a", ports(&q1), None);
        let tx = EndpointTransport::new(
            Arc::clone(&t),
            EndpointAddr::new("a", "in"),
            "edge",
        );
        q1.close(); // crash: queues die, publication stands
        let t2 = Arc::clone(&t);
        let repair = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            let q2 = queue();
            t2.publish("a", ports(&q2), None);
            q2
        });
        tx.send(Message::text("bridged")).unwrap();
        let q2 = repair.join().unwrap();
        assert_eq!(q2.pop().unwrap().as_text(), Some("bridged"));
        assert!(q1.is_empty());
    }

    #[test]
    fn transport_errors_on_unpublished_endpoint() {
        let t = EndpointTable::new();
        let tx = EndpointTransport::new(
            Arc::clone(&t),
            EndpointAddr::new("ghost", "in"),
            "edge",
        );
        assert!(tx.send(Message::text("x")).is_err());
        assert!(tx.try_send(Message::text("x")).is_err());
    }
}
