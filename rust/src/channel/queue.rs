//! Bounded blocking MPMC queue — the flake input/output buffer (§III: "a
//! flake has an input and an output queue for buffering de/serialized
//! messages") and the framework's backpressure primitive.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Error: the queue was closed.
#[derive(Debug, PartialEq, Eq)]
pub struct QueueClosed;

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded blocking queue usable from any number of producer/consumer
/// threads.  `push` blocks when full (backpressure), `pop` blocks when
/// empty.  `close()` wakes everyone; a closed queue still drains remaining
/// items before `pop` reports [`QueueClosed`].
pub struct SyncQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> SyncQueue<T> {
    pub fn new(capacity: usize) -> Self {
        SyncQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity.min(1024)),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking push; waits while full. Err if closed.
    pub fn push(&self, item: T) -> Result<(), QueueClosed> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.closed {
                return Err(QueueClosed);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).expect("queue poisoned");
        }
    }

    /// Non-blocking push; Err(item) when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking batch push: one lock acquisition amortized over the whole
    /// batch.  Respects capacity — when the queue fills mid-batch the
    /// producer waits for consumers to drain, exactly like repeated
    /// [`SyncQueue::push`] calls but without re-locking per message.
    /// Err if the queue is closed before every item is queued (items
    /// already queued stay consumable; the rest are dropped, matching the
    /// single-message `push` contract).
    pub fn push_batch(&self, items: Vec<T>) -> Result<(), QueueClosed> {
        if items.is_empty() {
            return Ok(());
        }
        let mut g = self.inner.lock().expect("queue poisoned");
        let mut queued = false;
        for item in items {
            loop {
                if g.closed {
                    if queued {
                        self.not_empty.notify_all();
                    }
                    return Err(QueueClosed);
                }
                if g.items.len() < self.capacity {
                    g.items.push_back(item);
                    queued = true;
                    break;
                }
                // Wake consumers for what is queued so far, then wait for
                // space.
                self.not_empty.notify_all();
                g = self.not_full.wait(g).expect("queue poisoned");
            }
        }
        self.not_empty.notify_all();
        Ok(())
    }

    /// Blocking pop; drains remaining items after close, then Err.
    pub fn pop(&self) -> Result<T, QueueClosed> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Ok(item);
            }
            if g.closed {
                return Err(QueueClosed);
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Pop with a timeout. `Ok(None)` on timeout.
    pub fn pop_timeout(
        &self,
        timeout: Duration,
    ) -> Result<Option<T>, QueueClosed> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(QueueClosed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned");
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Err(QueueClosed);
                }
                return Ok(None);
            }
        }
    }

    /// As [`SyncQueue::pop_timeout`], but increments `counter` under
    /// the queue lock when an item is handed out, so an observer that
    /// reads queue length and the counter never sees the item in
    /// *neither* place.  The flake worker loop uses this with the
    /// in-flight probe: quiesce/drain checks would otherwise race the
    /// window between a pop returning and the worker's own
    /// increment.  The caller decrements `counter` when done.
    pub fn pop_timeout_counted(
        &self,
        timeout: Duration,
        counter: &std::sync::atomic::AtomicUsize,
    ) -> Result<Option<T>, QueueClosed> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                counter
                    .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(QueueClosed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned");
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                if g.closed {
                    return Err(QueueClosed);
                }
                return Ok(None);
            }
        }
    }

    /// Blocking batch pop: waits for at least one item, then drains up to
    /// `max` under the same lock.  Does *not* wait for the batch to fill —
    /// batching is opportunistic, so latency matches [`SyncQueue::pop`].
    /// After close, remaining items drain first; then Err.
    pub fn pop_batch(&self, max: usize) -> Result<Vec<T>, QueueClosed> {
        let max = max.max(1);
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max);
                let out: Vec<T> = g.items.drain(..n).collect();
                self.not_full.notify_all();
                return Ok(out);
            }
            if g.closed {
                return Err(QueueClosed);
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Batch pop with a timeout.  `Ok(vec![])` on timeout; otherwise the
    /// semantics of [`SyncQueue::pop_batch`].
    pub fn pop_batch_timeout(
        &self,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<T>, QueueClosed> {
        let max = max.max(1);
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if !g.items.is_empty() {
                let n = g.items.len().min(max);
                let out: Vec<T> = g.items.drain(..n).collect();
                self.not_full.notify_all();
                return Ok(out);
            }
            if g.closed {
                return Err(QueueClosed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(Vec::new());
            }
            let (guard, _res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned");
            g = guard;
        }
    }

    /// Non-blocking drain of up to `max` items into `out`; returns how
    /// many were moved.  Ignores the closed flag — remaining items are
    /// always drainable.
    pub fn drain_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let mut g = self.inner.lock().expect("queue poisoned");
        let n = g.items.len().min(max);
        if n > 0 {
            out.extend(g.items.drain(..n));
            self.not_full.notify_all();
        }
        n
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        let item = g.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Visit every buffered item in FIFO order without removing it
    /// (non-destructive snapshot support for checkpointing).
    pub fn for_each(&self, mut f: impl FnMut(&T)) {
        let g = self.inner.lock().expect("queue poisoned");
        for item in g.items.iter() {
            f(item);
        }
    }

    /// Current number of buffered items.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("queue poisoned").closed
    }

    /// Close the queue: producers fail immediately, consumers drain whatever
    /// remains and then fail.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = SyncQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap(), i);
        }
    }

    #[test]
    fn blocks_on_full_until_pop() {
        let q = Arc::new(SyncQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.try_push(3).is_err());
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.push(3));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop().unwrap(), 1); // unblocks producer
        h.join().unwrap().unwrap();
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop().unwrap(), 3);
    }

    #[test]
    fn close_drains_then_errors() {
        let q = SyncQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).is_err());
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop(), Err(QueueClosed));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(SyncQueue::<i32>::new(4));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), Err(QueueClosed));
    }

    #[test]
    fn pop_timeout_returns_none() {
        let q = SyncQueue::<i32>::new(4);
        let got = q.pop_timeout(Duration::from_millis(10)).unwrap();
        assert!(got.is_none());
        q.push(7).unwrap();
        assert_eq!(
            q.pop_timeout(Duration::from_millis(10)).unwrap(),
            Some(7)
        );
    }

    #[test]
    fn push_batch_pop_batch_roundtrip() {
        let q = SyncQueue::new(64);
        q.push_batch((0..10).collect()).unwrap();
        assert_eq!(q.len(), 10);
        let first = q.pop_batch(4).unwrap();
        assert_eq!(first, vec![0, 1, 2, 3]);
        let rest = q.pop_batch(100).unwrap();
        assert_eq!(rest, (4..10).collect::<Vec<i32>>());
    }

    #[test]
    fn push_batch_blocks_on_capacity_until_drained() {
        let q = Arc::new(SyncQueue::new(4));
        let q2 = Arc::clone(&q);
        let producer = thread::spawn(move || q2.push_batch((0..12).collect()));
        let mut got = Vec::new();
        while got.len() < 12 {
            got.extend(q.pop_batch(4).unwrap());
        }
        producer.join().unwrap().unwrap();
        assert_eq!(got, (0..12).collect::<Vec<i32>>());
    }

    #[test]
    fn pop_batch_drains_then_reports_closed() {
        let q = SyncQueue::new(8);
        q.push_batch(vec![1, 2, 3]).unwrap();
        q.close();
        assert!(q.push_batch(vec![4]).is_err());
        assert_eq!(q.pop_batch(2).unwrap(), vec![1, 2]);
        assert_eq!(q.pop_batch(2).unwrap(), vec![3]);
        assert_eq!(q.pop_batch(2), Err(QueueClosed));
    }

    #[test]
    fn pop_batch_timeout_returns_empty() {
        let q = SyncQueue::<i32>::new(8);
        let got = q.pop_batch_timeout(4, Duration::from_millis(10)).unwrap();
        assert!(got.is_empty());
        q.push(9).unwrap();
        assert_eq!(
            q.pop_batch_timeout(4, Duration::from_millis(10)).unwrap(),
            vec![9]
        );
    }

    #[test]
    fn drain_into_is_nonblocking() {
        let q = SyncQueue::new(8);
        let mut out = Vec::new();
        assert_eq!(q.drain_into(&mut out, 4), 0);
        q.push_batch(vec![1, 2, 3]).unwrap();
        assert_eq!(q.drain_into(&mut out, 2), 2);
        assert_eq!(q.drain_into(&mut out, 2), 1);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn mpmc_stress_preserves_all_items() {
        let q = Arc::new(SyncQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for i in 0..250 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort();
        let mut want: Vec<i32> =
            (0..4).flat_map(|p| (0..250).map(move |i| p * 1000 + i)).collect();
        want.sort();
        assert_eq!(all, want);
    }
}
