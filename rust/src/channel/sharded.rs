//! Sharded MPMC queue: per-producer sub-queues with a round-robin
//! consumer sweep.
//!
//! [`super::SyncQueue`] serializes every producer on one mutex; under
//! fan-in (many upstream pellet instances pushing into one flake's input
//! port) producers convoy on that lock and throughput flatlines.  A
//! [`ShardedQueue`] splits the buffer into N independent shards.  Each
//! producer *thread* is pinned to one shard per queue (assigned
//! round-robin on first contact, stable afterwards), so producers on
//! different shards never contend; consumers sweep the shards
//! round-robin and drain in batches.
//!
//! Each shard is backed by one of two interchangeable primitives (the
//! [`ChannelBackend`] knob on `FlakeConfig`/`RuntimeOptions`):
//!
//! * [`ChannelBackend::Ring`] (default) — the lock-free
//!   [`super::RingQueue`]: atomic batch claims, no mutex on the hot
//!   path.
//! * [`ChannelBackend::Mutex`] — the original [`SyncQueue`], kept as
//!   the reference implementation so benches can report head-to-head
//!   numbers and the recompose/elasticity suites can run on both.
//!
//! Ordering contract: FIFO **per producer thread** (a thread's messages
//! stay in its shard, in order).  Cross-producer interleaving is
//! unspecified — the same contract a data-parallel flake already imposes
//! on its outputs, so the runtime loses nothing.
//!
//! Backpressure contract: `push` blocks when the producer's shard is full
//! (aggregate capacity is split evenly across shards; the ring backend
//! rounds each shard up to a power of two — [`ShardedQueue::capacity`]
//! reports the actual bound), and a closed queue drains every remaining
//! item before `pop` reports [`QueueClosed`] — identical on both
//! backends, per shard.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::queue::{QueueClosed, SyncQueue};
use super::ring::RingQueue;
use super::ChannelBackend;

/// Default shard count for flake input ports.
pub const DEFAULT_SHARDS: usize = 4;

/// One sub-queue, in either backend flavor.  Static dispatch: the
/// backend is fixed at construction, so the hot path pays one branch,
/// not a vtable.  (Variant sizes differ — the ring carries padded
/// counters — but shards are few and long-lived, so boxing would only
/// add an indirection to every hot-path op.)
#[allow(clippy::large_enum_variant)]
enum Shard<T> {
    Mutex(SyncQueue<T>),
    Ring(RingQueue<T>),
}

impl<T> Shard<T> {
    fn push(&self, item: T) -> Result<(), QueueClosed> {
        match self {
            Shard::Mutex(q) => q.push(item),
            Shard::Ring(q) => q.push(item),
        }
    }

    fn try_push(&self, item: T) -> Result<(), T> {
        match self {
            Shard::Mutex(q) => q.try_push(item),
            Shard::Ring(q) => q.try_push(item),
        }
    }

    fn push_batch(&self, items: Vec<T>) -> Result<(), QueueClosed> {
        match self {
            Shard::Mutex(q) => q.push_batch(items),
            Shard::Ring(q) => q.push_batch(items),
        }
    }

    fn drain_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        match self {
            Shard::Mutex(q) => q.drain_into(out, max),
            Shard::Ring(q) => q.drain_into(out, max),
        }
    }

    fn try_pop(&self) -> Option<T> {
        match self {
            Shard::Mutex(q) => q.try_pop(),
            Shard::Ring(q) => q.try_pop(),
        }
    }

    fn for_each(&self, f: impl FnMut(&T)) {
        match self {
            Shard::Mutex(q) => q.for_each(f),
            Shard::Ring(q) => q.for_each(f),
        }
    }

    fn len(&self) -> usize {
        match self {
            Shard::Mutex(q) => q.len(),
            Shard::Ring(q) => q.len(),
        }
    }

    fn capacity(&self) -> usize {
        match self {
            Shard::Mutex(q) => q.capacity(),
            Shard::Ring(q) => q.capacity(),
        }
    }

    fn close(&self) {
        match self {
            Shard::Mutex(q) => q.close(),
            Shard::Ring(q) => q.close(),
        }
    }

    /// Consumer-authoritative closed check: once true, an empty sweep
    /// means nothing more can arrive (the ring's `is_closed` is strict
    /// — closed *and* no in-flight publication).
    fn is_closed(&self) -> bool {
        match self {
            Shard::Mutex(q) => q.is_closed(),
            Shard::Ring(q) => q.is_closed(),
        }
    }
}

/// Bounded blocking MPMC queue sharded by producer thread.
pub struct ShardedQueue<T> {
    shards: Vec<Shard<T>>,
    /// Generation counter bumped on every push/close so sweeping
    /// consumers can sleep without missing items.
    signal: Mutex<u64>,
    not_empty: Condvar,
    /// Consumers registered on `not_empty`; producers skip the signal
    /// lock entirely while this is zero (the common case).
    waiters: AtomicUsize,
    /// Rotating sweep start so concurrent consumers fan out over shards.
    sweep: AtomicUsize,
    /// Next shard handed to a newly seen producer thread (round-robin
    /// per queue, so k producer threads cover min(k, shards) shards).
    next_producer: AtomicUsize,
    capacity: usize,
}

impl<T> ShardedQueue<T> {
    /// A queue with `shards` sub-queues sharing `capacity` total slots
    /// (each shard gets `capacity / shards`, at least 1), on the
    /// default [`ChannelBackend::Ring`] backend.
    pub fn new(shards: usize, capacity: usize) -> Self {
        ShardedQueue::with_backend(shards, capacity, ChannelBackend::Ring)
    }

    /// A queue on an explicit shard backend (see [`ChannelBackend`]).
    pub fn with_backend(
        shards: usize,
        capacity: usize,
        backend: ChannelBackend,
    ) -> Self {
        let shards = shards.max(1);
        let per_shard = (capacity / shards).max(1);
        let built: Vec<Shard<T>> = (0..shards)
            .map(|_| match backend {
                ChannelBackend::Mutex => {
                    Shard::Mutex(SyncQueue::new(per_shard))
                }
                ChannelBackend::Ring => {
                    Shard::Ring(RingQueue::new(per_shard))
                }
            })
            .collect();
        let capacity = built.iter().map(Shard::capacity).sum();
        ShardedQueue {
            shards: built,
            signal: Mutex::new(0),
            not_empty: Condvar::new(),
            waiters: AtomicUsize::new(0),
            sweep: AtomicUsize::new(0),
            next_producer: AtomicUsize::new(0),
            capacity,
        }
    }

    /// A queue with [`DEFAULT_SHARDS`] shards.
    pub fn with_default_shards(capacity: usize) -> Self {
        ShardedQueue::new(DEFAULT_SHARDS, capacity)
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Aggregate capacity across shards (the actual bound — the ring
    /// backend rounds each shard up to a power of two).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The calling thread's pinned shard for *this* queue.  Pins are
    /// assigned round-robin per queue on first contact, so k producer
    /// threads cover min(k, shards) shards exactly — a process-global
    /// thread id modulo shards would let unrelated threads alias
    /// producers onto one shard and silently re-introduce convoying.
    fn my_shard(&self) -> &Shard<T> {
        use std::cell::RefCell;
        let n = self.shards.len();
        if n == 1 {
            return &self.shards[0];
        }
        thread_local! {
            /// (queue identity, pinned shard) pairs for this thread.
            static PINS: RefCell<Vec<(usize, usize)>> =
                const { RefCell::new(Vec::new()) };
        }
        let key = self as *const ShardedQueue<T> as usize;
        let pin = PINS.with(|pins| {
            let mut pins = pins.borrow_mut();
            if let Some(i) = pins.iter().position(|(k, _)| *k == key) {
                // Move-to-front so the hot queue is an O(1) lookup.
                pins.swap(0, i);
                return pins[0].1;
            }
            // Entries are never evicted: dropping a live pin would let a
            // producer's stream straddle two shards and break the
            // per-producer FIFO contract.  The list grows only with the
            // distinct queues this thread has produced into, and a dead
            // queue's reused address recycles its old entry (the modulo
            // below keeps stale pins in range).
            let s = self.next_producer.fetch_add(1, Ordering::Relaxed) % n;
            pins.insert(0, (key, s));
            s
        });
        &self.shards[pin % n]
    }

    /// Wake sweeping consumers after a successful push.  Skipped while no
    /// consumer is registered; consumers guard the race with a short
    /// bounded wait, so a missed wakeup costs milliseconds, never a hang.
    fn bump(&self) {
        if self.waiters.load(Ordering::Acquire) > 0 {
            // The signal mutex only guards a wakeup counter — a
            // panic in some other holder cannot leave it in a bad
            // state, so recover from poisoning instead of cascading
            // the panic into every producer.
            let mut g = self
                .signal
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            *g = g.wrapping_add(1);
            self.not_empty.notify_all();
        }
    }

    /// Blocking push to this thread's shard; waits while that shard is
    /// full.  Err if closed.
    pub fn push(&self, item: T) -> Result<(), QueueClosed> {
        self.my_shard().push(item)?;
        self.bump();
        Ok(())
    }

    /// Non-blocking push; Err(item) when the shard is full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        self.my_shard().try_push(item)?;
        self.bump();
        Ok(())
    }

    /// Blocking batch push to this thread's shard: one shard-lock
    /// acquisition amortized over the batch (see
    /// [`SyncQueue::push_batch`]).
    pub fn push_batch(&self, items: Vec<T>) -> Result<(), QueueClosed> {
        if items.is_empty() {
            return Ok(());
        }
        let result = self.my_shard().push_batch(items);
        self.bump();
        result
    }

    /// One non-blocking round-robin sweep over all shards, draining up to
    /// `max` items into `out`.  Returns how many were taken.
    fn sweep_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        let n = self.shards.len();
        let start = self.sweep.fetch_add(1, Ordering::Relaxed) % n;
        let mut taken = 0;
        for k in 0..n {
            if taken >= max {
                break;
            }
            let shard = &self.shards[(start + k) % n];
            taken += shard.drain_into(out, max - taken);
        }
        taken
    }

    /// True once `close` has run to completion on every shard.
    pub fn is_closed(&self) -> bool {
        self.shards.iter().all(|s| s.is_closed())
    }

    /// Blocking batch pop: waits for at least one item anywhere, then
    /// sweeps the shards round-robin draining up to `max`.  After close,
    /// remaining items drain first; then Err.
    pub fn pop_batch(&self, max: usize) -> Result<Vec<T>, QueueClosed> {
        self.pop_batch_deadline(max, None)
            .map(|out| out.expect("no deadline, no timeout"))
    }

    /// Batch pop with a timeout; `Ok(vec![])` on timeout.
    pub fn pop_batch_timeout(
        &self,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<T>, QueueClosed> {
        let deadline = std::time::Instant::now() + timeout;
        self.pop_batch_deadline(max, Some(deadline))
            .map(|out| out.unwrap_or_default())
    }

    /// As [`ShardedQueue::pop_batch_timeout`], but appending into a
    /// caller-owned buffer so a hot consumer (the flake dispatcher)
    /// reuses one allocation across batches.  Returns how many items
    /// were appended; 0 on timeout.
    pub fn pop_batch_timeout_into(
        &self,
        out: &mut Vec<T>,
        max: usize,
        timeout: Duration,
    ) -> Result<usize, QueueClosed> {
        let deadline = std::time::Instant::now() + timeout;
        self.pop_batch_deadline_into(out, max, Some(deadline))
            .map(|n| n.unwrap_or(0))
    }

    /// Shared pop core.  `Ok(None)` only when a deadline was given and
    /// passed.
    fn pop_batch_deadline(
        &self,
        max: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<Option<Vec<T>>, QueueClosed> {
        let mut out = Vec::new();
        self.pop_batch_deadline_into(&mut out, max, deadline)
            .map(|n| n.map(|_| out))
    }

    /// Core of every blocking pop: appends into `out`, returns how many
    /// items were taken (`Ok(None)` only on a passed deadline).
    fn pop_batch_deadline_into(
        &self,
        out: &mut Vec<T>,
        max: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<Option<usize>, QueueClosed> {
        let max = max.max(1);
        loop {
            // Closed-before-sweep makes an empty sweep authoritative: no
            // push can land in any shard once every shard is closed.
            let closed = self.is_closed();
            let taken = self.sweep_into(out, max);
            if taken > 0 {
                return Ok(Some(taken));
            }
            if closed {
                return Err(QueueClosed);
            }
            if let Some(d) = deadline {
                if std::time::Instant::now() >= d {
                    return Ok(None);
                }
            }
            // Register as a waiter, re-sweep (an item may have landed
            // between the sweep above and taking the lock), then sleep.
            // The wait is bounded: a producer may observe waiters == 0
            // just before this registration becomes visible and skip its
            // wakeup, so never sleep unboundedly on the condvar alone.
            let guard = self
                .signal
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            self.waiters.fetch_add(1, Ordering::AcqRel);
            let taken = self.sweep_into(out, max);
            if taken > 0 {
                self.waiters.fetch_sub(1, Ordering::AcqRel);
                return Ok(Some(taken));
            }
            let mut wait = Duration::from_millis(5);
            if let Some(d) = deadline {
                let now = std::time::Instant::now();
                if now >= d {
                    self.waiters.fetch_sub(1, Ordering::AcqRel);
                    return Ok(None);
                }
                wait = wait.min(d - now);
            }
            let (reacquired, _timed_out) = self
                .not_empty
                .wait_timeout(guard, wait)
                .unwrap_or_else(|e| e.into_inner());
            drop(reacquired);
            self.waiters.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Blocking single pop (round-robin over shards).
    pub fn pop(&self) -> Result<T, QueueClosed> {
        self.pop_batch(1).map(|mut v| v.remove(0))
    }

    /// Single pop with a timeout; `Ok(None)` on timeout.
    pub fn pop_timeout(
        &self,
        timeout: Duration,
    ) -> Result<Option<T>, QueueClosed> {
        self.pop_batch_timeout(1, timeout)
            .map(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
    }

    /// Non-blocking pop (allocation-free; used per message by the
    /// synchronous-merge dispatcher).
    pub fn try_pop(&self) -> Option<T> {
        let n = self.shards.len();
        let start = self.sweep.fetch_add(1, Ordering::Relaxed) % n;
        for k in 0..n {
            if let Some(item) = self.shards[(start + k) % n].try_pop() {
                return Some(item);
            }
        }
        None
    }

    /// Non-blocking batch pop (one sweep, up to `max` items).
    pub fn try_pop_batch(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        self.sweep_into(&mut out, max);
        out
    }

    /// Non-blocking batch pop into a caller-owned buffer (one sweep, up
    /// to `max` items appended); returns how many were taken.
    pub fn try_pop_batch_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        self.sweep_into(out, max)
    }

    /// Destructively drain every buffered item, shard by shard
    /// (per-shard FIFO order preserved) — the consumer-rebinding
    /// primitive behind flake handoff: the buffered stream is taken
    /// from this queue's consumer and handed to another (see
    /// [`crate::flake::Flake::handoff`]).  Only sound once producers
    /// are quiesced; a concurrent push may land in an already-drained
    /// shard and be missed by this call.
    pub fn drain_all(&self) -> Vec<T> {
        let mut out = Vec::new();
        for s in &self.shards {
            while s.drain_into(&mut out, usize::MAX) > 0 {}
        }
        out
    }

    /// Total buffered items across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close every shard: producers fail immediately, consumers drain
    /// whatever remains and then fail.
    pub fn close(&self) {
        for s in &self.shards {
            s.close();
        }
        let mut g =
            self.signal.lock().unwrap_or_else(|e| e.into_inner());
        *g = g.wrapping_add(1);
        self.not_empty.notify_all();
    }
}

impl<T: Clone> ShardedQueue<T> {
    /// Non-destructive snapshot of every buffered item, shard by shard
    /// (per-shard FIFO order preserved).  Used by checkpointing, which
    /// pauses the flake dispatcher first — on the ring backend the walk
    /// is only sound while the consumer side is quiescent (concurrent
    /// producers are fine on both backends).
    pub fn snapshot(&self) -> Vec<T> {
        let mut out = Vec::new();
        for s in &self.shards {
            s.for_each(|item| out.push(item.clone()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    /// A thread that panics while holding the signal mutex must not
    /// brick the queue: the lock only guards a wakeup counter, so
    /// later pushes, pops and close recover from the poison and the
    /// queue still drains.
    #[test]
    fn queue_survives_signal_poisoning() {
        let q = Arc::new(ShardedQueue::new(2, 64));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let _ = thread::spawn(move || {
            let _g = q2.signal.lock().unwrap();
            panic!("poison the signal mutex");
        })
        .join();
        assert!(q.signal.is_poisoned());
        q.push(2).unwrap();
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(
            q.pop_timeout(Duration::from_millis(200)).unwrap(),
            Some(2)
        );
        q.close();
        assert!(q.push(3).is_err());
    }

    #[test]
    fn single_producer_fifo_order() {
        // 64 slots per shard: the single producer stays under its
        // shard's bound however threads map to shards.
        let q = ShardedQueue::new(4, 256);
        for i in 0..20 {
            q.push(i).unwrap();
        }
        let mut got = Vec::new();
        while let Some(v) = q.try_pop() {
            got.push(v);
        }
        // One thread pins one shard, so global FIFO holds.
        assert_eq!(got, (0..20).collect::<Vec<i32>>());
    }

    #[test]
    fn per_producer_order_survives_sweep() {
        // Consumer runs concurrently: producers may share a shard
        // (thread→shard mapping is process-global), so draining must not
        // wait for the producers to finish.
        let q = Arc::new(ShardedQueue::new(4, 64));
        let consumer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let mut got = Vec::new();
                while got.len() < 200 {
                    got.extend(q.pop_batch(16).unwrap());
                }
                got
            })
        };
        let handles: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Per-producer order survives the sweep: for each producer the
        // popped subsequence is ascending.
        let got = consumer.join().unwrap();
        let mut per = vec![Vec::new(); 4];
        for v in got {
            per[(v / 100) as usize].push(v % 100);
        }
        for (p, seq) in per.iter().enumerate() {
            assert_eq!(seq, &(0..50).collect::<Vec<i32>>(), "producer {p}");
        }
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(ShardedQueue::<i32>::new(2, 16));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.push(42).unwrap();
        assert_eq!(h.join().unwrap(), Ok(42));
    }

    #[test]
    fn close_drains_then_errors() {
        let q = ShardedQueue::new(2, 16);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).is_err());
        assert!(q.is_closed());
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop(), Err(QueueClosed));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(ShardedQueue::<i32>::new(2, 16));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), Err(QueueClosed));
    }

    #[test]
    fn pop_timeout_and_batch_timeout() {
        let q = ShardedQueue::<i32>::new(2, 16);
        assert_eq!(q.pop_timeout(Duration::from_millis(10)).unwrap(), None);
        assert!(q
            .pop_batch_timeout(8, Duration::from_millis(10))
            .unwrap()
            .is_empty());
        q.push_batch(vec![1, 2, 3]).unwrap();
        let got = q.pop_batch_timeout(8, Duration::from_millis(10)).unwrap();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn backpressure_per_shard() {
        // One shard of capacity 2 keeps shard assignment deterministic:
        // the batch push must block until a pop frees a slot.
        let q = Arc::new(ShardedQueue::new(1, 2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.try_push(3).is_err());
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.push_batch(vec![3, 4]));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        h.join().unwrap().unwrap();
        q.close();
        let mut rest = Vec::new();
        while let Ok(batch) = q.pop_batch(8) {
            rest.extend(batch);
        }
        assert_eq!(rest, vec![3, 4]);
    }

    #[test]
    fn drain_all_takes_everything() {
        let q = ShardedQueue::new(2, 16);
        q.push_batch(vec![1, 2, 3]).unwrap();
        let mut got = q.drain_all();
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
        assert!(q.is_empty());
        assert!(q.drain_all().is_empty());
    }

    #[test]
    fn snapshot_is_nondestructive() {
        let q = ShardedQueue::new(2, 16);
        q.push_batch(vec![1, 2, 3]).unwrap();
        let snap = q.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn mutex_backend_keeps_contract() {
        let q = ShardedQueue::with_backend(2, 16, ChannelBackend::Mutex);
        q.push_batch(vec![1, 2, 3]).unwrap();
        assert_eq!(q.snapshot().len(), 3);
        q.close();
        assert!(q.push(4).is_err());
        let mut got = Vec::new();
        while let Ok(b) = q.pop_batch(8) {
            got.extend(b);
        }
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn mpmc_stress_no_loss() {
        let q = Arc::new(ShardedQueue::new(4, 64));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for chunk in 0..25 {
                        let batch: Vec<i32> = (0..10)
                            .map(|i| p * 1000 + chunk * 10 + i)
                            .collect();
                        q.push_batch(batch).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(batch) = q.pop_batch(16) {
                        got.extend(batch);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort();
        let mut want: Vec<i32> = (0..4)
            .flat_map(|p| (0..250).map(move |i| p * 1000 + i))
            .collect();
        want.sort();
        assert_eq!(all, want);
    }
}
