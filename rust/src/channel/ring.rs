//! Lock-free bounded MPMC ring buffer — the atomic fast path under every
//! [`super::ShardedQueue`] shard.
//!
//! The mutex [`super::SyncQueue`] costs one lock round-trip (and often a
//! condvar wake) per operation; under fan-in that lock is the per-message
//! floor of the whole data plane.  [`RingQueue`] replaces it with a
//! Vyukov-style ring of power-of-two capacity: each slot carries a
//! sequence number, producers claim slots by bumping an atomic
//! `enqueue_pos`, consumers claim them by bumping `dequeue_pos`, and the
//! per-slot sequence hand-off publishes the data — no lock anywhere on
//! the hot path.  On top of the classic design, both sides claim whole
//! **batches** with a single compare-and-swap: scan forward from the
//! head counting available slots (every slot's sequence is checked —
//! with concurrent producers/consumers, availability is NOT guaranteed
//! to be contiguous beyond the first gap, so the scan stops there),
//! then claim the whole run with one CAS.  A 64-message batch
//! therefore moves with one CAS per side instead of 64 lock
//! round-trips, at the cost of a 64-load scan.
//!
//! # Contract (identical to `SyncQueue`, per queue)
//!
//! * FIFO in claim order; a single producer's items never reorder.
//! * `push` blocks while full (backpressure); `try_push` refuses.
//! * `close()` fails producers immediately; consumers drain every
//!   remaining item before seeing [`QueueClosed`].  Close-then-drain is
//!   loss-free: `close()` waits for in-flight publications (tracked by a
//!   `pushing` guard counter) so a `push` that returned `Ok` is always
//!   visible to a post-close drain — the handoff primitive
//!   `recompose`/checkpointing depend on.
//!
//! # Parking
//!
//! Blocking ops park on an eventcount-style condvar (generation counter
//! under a mutex, `waiters` fast-path so producers/consumers skip the
//! lock entirely while nobody sleeps).  Waits are bounded (≤ 1 ms) so a
//! lost wakeup costs a beat, never a hang — the same discipline
//! [`super::ShardedQueue`] uses for its cross-shard sweep.
//!
//! # Snapshot caveat
//!
//! [`RingQueue::for_each`] walks published slots without claiming them.
//! That is only sound while the consumer side is quiescent (checkpoints
//! pause the flake dispatcher first); concurrent producers are fine.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::queue::QueueClosed;
use crate::telemetry;

/// Upper bound for one parked wait; bounds the cost of a lost wakeup.
const PARK: Duration = Duration::from_millis(1);

struct Slot<T> {
    /// Vyukov sequence: `pos` = free for the producer claiming position
    /// `pos`; `pos + 1` = published for the consumer claiming `pos`;
    /// `pos + capacity` = freed, ready for the next lap.
    seq: AtomicU64,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Keep the two claim counters on separate cache lines so producer and
/// consumer CAS traffic does not false-share.
#[repr(align(64))]
struct Padded<T>(T);

/// Lock-free bounded MPMC queue (see module docs).
pub struct RingQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: u64,
    capacity: u64,
    enqueue_pos: Padded<AtomicU64>,
    dequeue_pos: Padded<AtomicU64>,
    closed: AtomicBool,
    /// Producers inside a claim/publish critical section.  `close()`
    /// waits for this to reach zero so post-close drains are complete.
    pushing: AtomicUsize,
    /// Eventcount parking: generation bumped under `signal` on every
    /// wake; waiter counts let the fast path skip the lock.
    signal: Mutex<u64>,
    not_full: Condvar,
    not_empty: Condvar,
    push_waiters: AtomicUsize,
    pop_waiters: AtomicUsize,
}

// SAFETY: slots are handed between threads through the seq protocol;
// a value is written by exactly one claiming producer and read by
// exactly one claiming consumer.
unsafe impl<T: Send> Send for RingQueue<T> {}
unsafe impl<T: Send> Sync for RingQueue<T> {}

impl<T> RingQueue<T> {
    /// A ring with at least `capacity` slots (rounded up to the next
    /// power of two; see [`RingQueue::capacity`] for the actual bound).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1).next_power_of_two() as u64;
        let slots = (0..cap)
            .map(|i| Slot {
                seq: AtomicU64::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        RingQueue {
            slots,
            mask: cap - 1,
            capacity: cap,
            enqueue_pos: Padded(AtomicU64::new(0)),
            dequeue_pos: Padded(AtomicU64::new(0)),
            closed: AtomicBool::new(false),
            pushing: AtomicUsize::new(0),
            signal: Mutex::new(0),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            push_waiters: AtomicUsize::new(0),
            pop_waiters: AtomicUsize::new(0),
        }
    }

    /// Actual slot count (requested capacity rounded up to a power of
    /// two).
    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }

    /// Buffered item count (approximate under concurrency).
    pub fn len(&self) -> usize {
        let e = self.enqueue_pos.0.load(Ordering::Acquire);
        let d = self.dequeue_pos.0.load(Ordering::Acquire);
        e.saturating_sub(d) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once `close` ran *and* every in-flight publication landed.
    /// The strict form makes the check authoritative for consumers: an
    /// empty claim scan after `is_closed()` returns `true` means
    /// nothing more can ever appear.  (Producers fail from the moment
    /// the close flag is set, before this reports `true`.)
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
            && self.pushing.load(Ordering::SeqCst) == 0
    }

    /// Close the queue: producers fail immediately, consumers drain
    /// whatever remains and then fail.  Returns only after every
    /// in-flight publication completed, so `close()` followed by a
    /// drain observes every `push` that reported success.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        // In-flight producers hold `pushing`; they never park while
        // holding it, so this wait is a few instructions long.
        while self.pushing.load(Ordering::SeqCst) > 0 {
            std::hint::spin_loop();
        }
        // The signal mutex only guards a wakeup counter, so a panic
        // in another holder leaves nothing inconsistent — recover
        // from poisoning rather than cascading the panic into every
        // thread that touches the queue afterwards.
        let mut seq =
            self.signal.lock().unwrap_or_else(|e| e.into_inner());
        *seq = seq.wrapping_add(1);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Enter a publish critical section; false when closed.
    #[inline]
    fn begin_push(&self) -> bool {
        self.pushing.fetch_add(1, Ordering::SeqCst);
        if self.closed.load(Ordering::SeqCst) {
            self.pushing.fetch_sub(1, Ordering::SeqCst);
            return false;
        }
        true
    }

    #[inline]
    fn end_push(&self) {
        self.pushing.fetch_sub(1, Ordering::SeqCst);
    }

    /// Claim up to `max` contiguous slots for this producer.  Returns
    /// the starting position and count, or `None` when the ring is
    /// full.  One CAS per successful claim, however large the batch.
    fn claim(&self, max: usize) -> Option<(u64, usize)> {
        let max = max.min(self.capacity as usize).max(1);
        loop {
            let pos = self.enqueue_pos.0.load(Ordering::Relaxed);
            // Count forward until the first still-occupied slot,
            // checking every sequence: concurrent batch-claiming
            // consumers may free later slots before earlier ones, so
            // only the contiguous prefix is claimable.
            let mut k = 0usize;
            while k < max {
                let p = pos + k as u64;
                let seq = self.slots[(p & self.mask) as usize]
                    .seq
                    .load(Ordering::Acquire);
                if seq != p {
                    break;
                }
                k += 1;
            }
            if k == 0 {
                let seq = self.slots[(pos & self.mask) as usize]
                    .seq
                    .load(Ordering::Acquire);
                if seq < pos {
                    return None; // genuinely full (previous lap)
                }
                continue; // lost a race with another producer
            }
            let cas = self.enqueue_pos.0.compare_exchange_weak(
                pos,
                pos + k as u64,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            if cas.is_ok() {
                return Some((pos, k));
            }
        }
    }

    /// Write one item into a claimed position and publish it.
    #[inline]
    fn publish(&self, pos: u64, item: T) {
        let slot = &self.slots[(pos & self.mask) as usize];
        unsafe { (*slot.val.get()).write(item) };
        slot.seq.store(pos + 1, Ordering::Release);
    }

    /// Non-blocking push; `Err(item)` when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        if !self.begin_push() {
            return Err(item);
        }
        match self.claim(1) {
            Some((pos, _)) => {
                self.publish(pos, item);
                self.end_push();
                self.wake_pop();
                Ok(())
            }
            None => {
                self.end_push();
                Err(item)
            }
        }
    }

    /// Blocking push; waits while full.  Err if closed.
    pub fn push(&self, item: T) -> Result<(), QueueClosed> {
        let mut item = item;
        loop {
            match self.try_push(item) {
                Ok(()) => return Ok(()),
                Err(v) => {
                    if self.is_closed() {
                        return Err(QueueClosed);
                    }
                    item = v;
                    self.park_push();
                }
            }
        }
    }

    /// Blocking batch push: the whole batch claims slots with one CAS
    /// per contiguous free run.  Blocks while full, exactly like
    /// repeated [`RingQueue::push`] calls.  Err once the queue closes
    /// (items already queued stay consumable; the rest are dropped,
    /// matching [`super::SyncQueue::push_batch`]).
    pub fn push_batch(&self, items: Vec<T>) -> Result<(), QueueClosed> {
        let mut it = items.into_iter();
        loop {
            let remaining = it.len();
            if remaining == 0 {
                return Ok(());
            }
            if !self.begin_push() {
                return Err(QueueClosed);
            }
            match self.claim(remaining) {
                Some((pos, k)) => {
                    for i in 0..k {
                        let item =
                            it.next().expect("claimed <= remaining");
                        self.publish(pos + i as u64, item);
                    }
                    self.end_push();
                    self.wake_pop();
                }
                None => {
                    self.end_push();
                    self.park_push();
                }
            }
        }
    }

    /// Claim and move out up to `max` published items, appending to
    /// `out`.  One CAS per successful claim.  Returns how many moved.
    fn take_batch(&self, out: &mut Vec<T>, max: usize) -> usize {
        let max = max.min(self.capacity as usize);
        if max == 0 {
            return 0;
        }
        loop {
            let pos = self.dequeue_pos.0.load(Ordering::Relaxed);
            // Count forward until the first unpublished slot, checking
            // every sequence: a producer batch-claiming [64, 128) may
            // publish before the claimant of [0, 64) does, so only the
            // contiguous published prefix is takeable.
            let mut k = 0usize;
            while k < max {
                let p = pos + k as u64;
                let seq = self.slots[(p & self.mask) as usize]
                    .seq
                    .load(Ordering::Acquire);
                if seq != p + 1 {
                    break;
                }
                k += 1;
            }
            if k == 0 {
                let seq = self.slots[(pos & self.mask) as usize]
                    .seq
                    .load(Ordering::Acquire);
                if seq < pos + 1 {
                    return 0; // empty (or head not yet published)
                }
                continue; // lost a race with another consumer
            }
            let cas = self.dequeue_pos.0.compare_exchange_weak(
                pos,
                pos + k as u64,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            if cas.is_ok() {
                out.reserve(k);
                for i in 0..k {
                    let p = pos + i as u64;
                    let slot = &self.slots[(p & self.mask) as usize];
                    let val =
                        unsafe { (*slot.val.get()).assume_init_read() };
                    slot.seq.store(p + self.capacity, Ordering::Release);
                    out.push(val);
                }
                self.wake_push();
                return k;
            }
        }
    }

    /// Non-blocking drain of up to `max` items into `out`; returns how
    /// many moved.  Ignores the closed flag — remaining items are
    /// always drainable.
    pub fn drain_into(&self, out: &mut Vec<T>, max: usize) -> usize {
        self.take_batch(out, max)
    }

    /// Non-blocking pop.  Allocation-free: claims one slot directly
    /// instead of routing through the batch path's `Vec`.
    pub fn try_pop(&self) -> Option<T> {
        loop {
            let pos = self.dequeue_pos.0.load(Ordering::Relaxed);
            let slot = &self.slots[(pos & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq < pos + 1 {
                return None; // empty (or head not yet published)
            }
            if seq > pos + 1 {
                continue; // lost a race with another consumer
            }
            let cas = self.dequeue_pos.0.compare_exchange_weak(
                pos,
                pos + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            if cas.is_ok() {
                let val = unsafe { (*slot.val.get()).assume_init_read() };
                slot.seq.store(pos + self.capacity, Ordering::Release);
                self.wake_push();
                return Some(val);
            }
        }
    }

    /// Blocking batch pop: waits for at least one item, drains up to
    /// `max`.  After close, remaining items drain first; then Err.
    pub fn pop_batch(&self, max: usize) -> Result<Vec<T>, QueueClosed> {
        self.pop_batch_deadline(max, None)
            .map(|out| out.expect("no deadline, no timeout"))
    }

    /// Batch pop with a timeout; `Ok(vec![])` on timeout.
    pub fn pop_batch_timeout(
        &self,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<T>, QueueClosed> {
        self.pop_batch_deadline(max, Some(Instant::now() + timeout))
            .map(|out| out.unwrap_or_default())
    }

    /// Blocking single pop; drains remaining items after close, then
    /// Err.  Allocation-free (see [`RingQueue::try_pop`]).
    pub fn pop(&self) -> Result<T, QueueClosed> {
        loop {
            let closed = self.is_closed();
            if let Some(v) = self.try_pop() {
                return Ok(v);
            }
            if closed {
                return Err(QueueClosed);
            }
            self.park_pop(None);
        }
    }

    /// Single pop with a timeout; `Ok(None)` on timeout.
    /// Allocation-free (see [`RingQueue::try_pop`]).
    pub fn pop_timeout(
        &self,
        timeout: Duration,
    ) -> Result<Option<T>, QueueClosed> {
        let deadline = Instant::now() + timeout;
        loop {
            let closed = self.is_closed();
            if let Some(v) = self.try_pop() {
                return Ok(Some(v));
            }
            if closed {
                return Err(QueueClosed);
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
            self.park_pop(Some(deadline));
        }
    }

    fn pop_batch_deadline(
        &self,
        max: usize,
        deadline: Option<Instant>,
    ) -> Result<Option<Vec<T>>, QueueClosed> {
        let max = max.max(1);
        let mut out = Vec::new();
        loop {
            // Closed-before-take makes an empty take authoritative:
            // once the strict `is_closed` holds, no publication can
            // still land.
            let closed = self.is_closed();
            if self.take_batch(&mut out, max) > 0 {
                return Ok(Some(out));
            }
            if closed {
                return Err(QueueClosed);
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Ok(None);
                }
            }
            self.park_pop(deadline);
        }
    }

    /// Visit every published item in FIFO order without claiming it.
    /// Only sound while the consumer side is quiescent (see module
    /// docs); concurrent producers are fine — the walk stops at the
    /// first unpublished slot.
    pub fn for_each(&self, mut f: impl FnMut(&T)) {
        let mut pos = self.dequeue_pos.0.load(Ordering::Acquire);
        loop {
            let slot = &self.slots[(pos & self.mask) as usize];
            if slot.seq.load(Ordering::Acquire) != pos + 1 {
                return;
            }
            f(unsafe { (*slot.val.get()).assume_init_ref() });
            pos += 1;
        }
    }

    // --- parking ----------------------------------------------------------

    /// Wake consumers after publishing; skipped while none sleep.
    #[inline]
    fn wake_pop(&self) {
        if self.pop_waiters.load(Ordering::SeqCst) > 0 {
            let mut seq = self
                .signal
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            *seq = seq.wrapping_add(1);
            self.not_empty.notify_all();
        }
    }

    /// Wake producers after freeing slots; skipped while none sleep.
    #[inline]
    fn wake_push(&self) {
        if self.push_waiters.load(Ordering::SeqCst) > 0 {
            let mut seq = self
                .signal
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            *seq = seq.wrapping_add(1);
            self.not_full.notify_all();
        }
    }

    /// Park until slots may have freed.  Bounded: a wakeup lost to the
    /// register/notify race costs at most [`PARK`].  Already the slow
    /// path (mutex + condvar), so the telemetry stamp is free relative
    /// to the wait itself; the lock-free fast path records nothing.
    fn park_push(&self) {
        let stamp = telemetry::enabled().then(Instant::now);
        let guard =
            self.signal.lock().unwrap_or_else(|e| e.into_inner());
        self.push_waiters.fetch_add(1, Ordering::SeqCst);
        let (_g, _) = self
            .not_full
            .wait_timeout(guard, PARK)
            .unwrap_or_else(|e| e.into_inner());
        self.push_waiters.fetch_sub(1, Ordering::SeqCst);
        if let Some(t) = stamp {
            telemetry::hist_ring_push_wait()
                .record(t.elapsed().as_nanos() as u64);
        }
    }

    /// Park until items may have arrived (bounded, like `park_push`).
    fn park_pop(&self, deadline: Option<Instant>) {
        let stamp = telemetry::enabled().then(Instant::now);
        let mut wait = PARK;
        if let Some(d) = deadline {
            let now = Instant::now();
            if now >= d {
                return;
            }
            wait = wait.min(d - now);
        }
        let guard =
            self.signal.lock().unwrap_or_else(|e| e.into_inner());
        self.pop_waiters.fetch_add(1, Ordering::SeqCst);
        let (_g, _) = self
            .not_empty
            .wait_timeout(guard, wait)
            .unwrap_or_else(|e| e.into_inner());
        self.pop_waiters.fetch_sub(1, Ordering::SeqCst);
        if let Some(t) = stamp {
            telemetry::hist_ring_pop_wait()
                .record(t.elapsed().as_nanos() as u64);
        }
    }
}

impl<T> Drop for RingQueue<T> {
    fn drop(&mut self) {
        // Claimed exclusively (&mut self): drop whatever is still
        // published.
        while self.try_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    /// A panic while holding the signal mutex (wakeup counter only)
    /// must not brick the ring: push, pop and close all recover from
    /// the poison and the queue still drains.
    #[test]
    fn ring_survives_signal_poisoning() {
        let q = Arc::new(RingQueue::new(4));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let _ = thread::spawn(move || {
            let _g = q2.signal.lock().unwrap();
            panic!("poison the signal mutex");
        })
        .join();
        assert!(q.signal.is_poisoned());
        q.push(2).unwrap();
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        q.close();
        assert_eq!(q.push(3), Err(QueueClosed));
    }

    #[test]
    fn fifo_order_and_capacity_rounding() {
        let q = RingQueue::new(10);
        assert_eq!(q.capacity(), 16);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap(), i);
        }
    }

    #[test]
    fn try_push_refuses_when_full() {
        let q = RingQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = Arc::new(RingQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.push(3));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop().unwrap(), 3);
    }

    #[test]
    fn close_drains_then_errors() {
        let q = RingQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(QueueClosed));
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop(), Err(QueueClosed));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(RingQueue::<i32>::new(4));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), Err(QueueClosed));
    }

    #[test]
    fn pop_timeout_returns_none() {
        let q = RingQueue::<i32>::new(4);
        assert_eq!(q.pop_timeout(Duration::from_millis(10)).unwrap(), None);
        q.push(7).unwrap();
        assert_eq!(
            q.pop_timeout(Duration::from_millis(10)).unwrap(),
            Some(7)
        );
    }

    #[test]
    fn batch_roundtrip_blocks_on_capacity() {
        let q = Arc::new(RingQueue::new(4));
        let q2 = Arc::clone(&q);
        let prod = thread::spawn(move || q2.push_batch((0..12).collect()));
        let mut got = Vec::new();
        while got.len() < 12 {
            got.extend(q.pop_batch(4).unwrap());
        }
        prod.join().unwrap().unwrap();
        assert_eq!(got, (0..12).collect::<Vec<i32>>());
    }

    #[test]
    fn for_each_is_nondestructive() {
        let q = RingQueue::new(8);
        q.push_batch(vec![1, 2, 3]).unwrap();
        let mut seen = Vec::new();
        q.for_each(|v| seen.push(*v));
        assert_eq!(seen, vec![1, 2, 3]);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn drop_releases_buffered_items() {
        let q = RingQueue::new(8);
        let item = Arc::new(());
        q.push(Arc::clone(&item)).unwrap();
        q.push(Arc::clone(&item)).unwrap();
        drop(q);
        assert_eq!(Arc::strong_count(&item), 1);
    }

    #[test]
    fn mpmc_stress_no_loss() {
        let q = Arc::new(RingQueue::new(64));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut i = 0;
                    while i < 250 {
                        let k = (i % 7 + 1).min(250 - i);
                        let batch: Vec<i32> =
                            (i..i + k).map(|j| p * 1000 + j).collect();
                        q.push_batch(batch).unwrap();
                        i += k;
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(batch) = q.pop_batch(16) {
                        got.extend(batch);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort();
        let mut want: Vec<i32> = (0..4)
            .flat_map(|p| (0..250).map(move |i| p * 1000 + i))
            .collect();
        want.sort();
        assert_eq!(all, want);
    }

    #[test]
    fn close_during_racing_pushes_loses_no_acked_item() {
        // Every push that returns Ok must be drainable after close():
        // the pushing-guard handshake in close() is what makes the
        // recompose handoff loss-free.
        for _ in 0..20 {
            let q = Arc::new(RingQueue::new(64));
            let producers: Vec<_> = (0..3)
                .map(|p| {
                    let q = Arc::clone(&q);
                    thread::spawn(move || {
                        let mut acked = 0usize;
                        for i in 0..200 {
                            if q.try_push(p * 1000 + i).is_ok() {
                                acked += 1;
                            }
                        }
                        acked
                    })
                })
                .collect();
            thread::sleep(Duration::from_micros(50));
            q.close();
            // Authoritative drain immediately after close returns.
            let mut drained = Vec::new();
            while q.drain_into(&mut drained, usize::MAX) > 0 {}
            let acked: usize =
                producers.into_iter().map(|h| h.join().unwrap()).sum();
            // Stragglers that raced close got Err; everything acked
            // before close() returned is in the drain.
            assert!(drained.len() <= acked);
            let mut rest = Vec::new();
            while q.drain_into(&mut rest, usize::MAX) > 0 {}
            assert_eq!(
                drained.len() + rest.len(),
                acked,
                "acked push missing after close+drain"
            );
        }
    }
}
