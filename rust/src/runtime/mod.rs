//! PJRT runtime bridge: load the AOT artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust request path.
//!
//! Interchange is HLO **text** (`artifacts/*.hlo.txt`): jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids.  `manifest.json` (parsed
//! with [`crate::util::json`]) names each entry point and its input shapes
//! so callers can validate before dispatch.
//!
//! One loaded kernel per entry point; compilation happens once at load,
//! execution is thread-safe behind an internal mutex (the PJRT CPU client is
//! not documented re-entrant through this binding, and the flake layer
//! provides the parallelism we need across pellet instances).
//!
//! The PJRT bridge needs the vendored `xla` binding, which the offline
//! build environment may not provide, so everything touching it is gated
//! behind the off-by-default `xla` cargo feature.  Without the feature
//! the manifest/tensor model still compiles and [`XlaRuntime::load`]
//! returns a runtime error, keeping callers (CLI, clustering app,
//! benches) source-compatible.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "xla")]
use std::sync::Mutex;

use crate::error::{FloeError, Result};
use crate::util::json::Json;

/// Tensor metadata from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point description from `manifest.json`.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<EntrySpec>,
    /// Model configuration (batch, dim, n_bands, band_width, n_clusters).
    pub config: HashMap<String, usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let mut config = HashMap::new();
        if let Some(obj) = root.get("config").and_then(|c| c.as_obj()) {
            for (k, v) in obj {
                if let Some(n) = v.as_usize() {
                    config.insert(k.clone(), n);
                }
            }
        }
        let entries_obj = root
            .get("entries")
            .and_then(|e| e.as_obj())
            .ok_or_else(|| {
                FloeError::Parse("manifest: missing 'entries'".into())
            })?;
        let mut entries = Vec::new();
        for (name, e) in entries_obj {
            let file = e
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| {
                    FloeError::Parse(format!("manifest: {name}: no file"))
                })?
                .to_string();
            let mut inputs = Vec::new();
            for inp in e
                .get("inputs")
                .and_then(|i| i.as_arr())
                .unwrap_or(&[])
            {
                let shape = inp
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .map(|a| {
                        a.iter().filter_map(|j| j.as_usize()).collect()
                    })
                    .unwrap_or_default();
                let dtype = inp
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(TensorSpec { shape, dtype });
            }
            entries.push(EntrySpec { name: name.clone(), file, inputs });
        }
        Ok(Manifest { entries, config })
    }

    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config.get(key).copied().ok_or_else(|| {
            FloeError::Parse(format!("manifest: missing config '{key}'"))
        })
    }
}

/// Input tensor handed to [`XlaRuntime::execute`].
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "float32",
            Tensor::I32 { .. } => "int32",
        }
    }

    /// Borrow f32 payload (None for other dtypes).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "xla")]
    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => Err(FloeError::Runtime(format!(
                "unsupported output element type {other:?}"
            ))),
        }
    }
}

#[cfg(feature = "xla")]
struct RuntimeInner {
    client: xla::PjRtClient,
    /// Entry name -> compiled executable.
    kernels: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The PJRT client plus every kernel from an artifact directory.
///
/// All access to the underlying xla objects is serialized behind one
/// mutex: the published `xla` 0.1.6 binding uses non-atomic `Rc` handles
/// internally, so the objects themselves are not thread-safe even though
/// the PJRT CPU runtime is.  The flake layer provides request-level
/// parallelism; a kernel call is one batched XLA execution.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    inner: Mutex<RuntimeInner>,
    specs: HashMap<String, EntrySpec>,
    pub manifest: Manifest,
    dir: PathBuf,
}

// SAFETY: every xla object (client, executables, and the transient
// literals/buffers created during execute) is owned by `RuntimeInner` and
// only touched while holding `self.inner`; no Rc handle ever crosses the
// lock boundary, so the non-atomic refcounts are never raced.
#[cfg(feature = "xla")]
unsafe impl Send for XlaRuntime {}
#[cfg(feature = "xla")]
unsafe impl Sync for XlaRuntime {}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Create a CPU PJRT client and load+compile every manifest entry in
    /// `dir` (typically `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            FloeError::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut kernels = HashMap::new();
        let mut specs = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| {
                    FloeError::Runtime("non-utf8 artifact path".into())
                })?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            crate::log_debug!("runtime: compiled {}", entry.name);
            kernels.insert(entry.name.clone(), exe);
            specs.insert(entry.name.clone(), entry.clone());
        }
        crate::log_info!(
            "runtime: loaded {} kernels from {} (platform {})",
            kernels.len(),
            dir.display(),
            client.platform_name()
        );
        Ok(XlaRuntime {
            inner: Mutex::new(RuntimeInner { client, kernels }),
            specs,
            manifest,
            dir,
        })
    }

    /// Validate inputs against the manifest spec, execute the named
    /// kernel, and unpack the result tuple.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(FloeError::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(spec.inputs.iter()).enumerate()
        {
            if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                return Err(FloeError::Runtime(format!(
                    "{name}: input {i} is {:?}/{}, expected {:?}/{}",
                    t.shape(),
                    t.dtype(),
                    s.shape,
                    s.dtype
                )));
            }
        }
        let inner = self.inner.lock().expect("runtime poisoned");
        let exe = inner.kernels.get(name).expect("spec checked");
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        drop(inner);
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Manifest spec for an entry point.
    pub fn spec(&self, name: &str) -> Result<&EntrySpec> {
        self.specs.get(name).ok_or_else(|| {
            FloeError::Runtime(format!(
                "no kernel '{name}' in {}",
                self.dir.display()
            ))
        })
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn platform_name(&self) -> String {
        self.inner
            .lock()
            .expect("runtime poisoned")
            .client
            .platform_name()
    }
}

/// Stub runtime used when the crate is built without the `xla` feature:
/// same API surface, but [`XlaRuntime::load`] reports that PJRT is
/// unavailable instead of compiling kernels.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    specs: HashMap<String, EntrySpec>,
    pub manifest: Manifest,
    dir: PathBuf,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Always fails: the PJRT bridge is compiled out.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        Err(FloeError::Runtime(format!(
            "cannot load kernels from {}: built without the 'xla' \
             feature (PJRT bridge compiled out)",
            dir.as_ref().display()
        )))
    }

    /// Always fails: the PJRT bridge is compiled out.
    pub fn execute(
        &self,
        name: &str,
        _inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        Err(FloeError::Runtime(format!(
            "cannot execute '{name}': built without the 'xla' feature"
        )))
    }

    /// Manifest spec for an entry point.
    pub fn spec(&self, name: &str) -> Result<&EntrySpec> {
        self.specs.get(name).ok_or_else(|| {
            FloeError::Runtime(format!(
                "no kernel '{name}' in {}",
                self.dir.display()
            ))
        })
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.specs.keys().map(|s| s.as_str()).collect()
    }

    pub fn platform_name(&self) -> String {
        "unavailable (no 'xla' feature)".to_string()
    }
}

/// Locate the artifact directory: `FLOE_ARTIFACTS` env, else `artifacts/`
/// relative to the working directory or the crate root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("FLOE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            r#"{"config": {"batch": 32, "dim": 64},
                "entries": {
                  "bucketize": {"file": "bucketize.hlo.txt",
                    "inputs": [{"shape": [32, 64], "dtype": "float32"},
                               {"shape": [64, 96], "dtype": "float32"}]}}}"#,
        )
        .unwrap();
        assert_eq!(m.config_usize("batch").unwrap(), 32);
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.name, "bucketize");
        assert_eq!(e.inputs[1].shape, vec![64, 96]);
        assert_eq!(e.inputs[0].elements(), 32 * 64);
    }

    #[test]
    fn manifest_rejects_missing_entries() {
        assert!(Manifest::parse(r#"{"config": {}}"#).is_err());
        assert!(Manifest::parse("[]").is_err());
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), "float32");
        assert_eq!(t.as_f32().unwrap().len(), 6);
        let i = Tensor::i32(&[4], vec![1, 2, 3, 4]);
        assert_eq!(i.dtype(), "int32");
        assert_eq!(i.as_i32().unwrap(), &[1, 2, 3, 4]);
    }
}
