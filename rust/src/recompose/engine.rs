//! The recomposition engine: executes a compiled [`RecomposePlan`]
//! against a live [`crate::coordinator::RunningDataflow`] with
//! **pause → buffer-at-upstream → rewire → resume** semantics.
//!
//! Execution phases (see `mod.rs` for the full design notes):
//!
//! 1. **Prepare** (no impact on the stream): compile the plan, resolve
//!    every pellet factory, allocate containers and spawn the new /
//!    replacement flakes.  They idle unwired; any failure here aborts
//!    with zero side effects on the flow.
//! 2. **Quiesce**: pause the upstream frontier and wait for its
//!    in-flight compute to drain.  Messages keep arriving and buffer
//!    in the paused flakes' input queues (bounded, so injectors feel
//!    ordinary backpressure, never loss).
//! 3. **Landmark**: every rewired source broadcasts a
//!    [`Landmark::Recompose`] so downstream pellets observe a clean
//!    pre/post cut in their streams.
//! 4. **Cut-over** (under the topology write lock, so ingress resolves
//!    either the old or the new topology, never a mix): relocated
//!    flakes hand their state + buffered input to their replacements
//!    via [`crate::flake::FlakeCheckpoint`], then **rebind**: the
//!    replacement republishes the moved flake's logical endpoints
//!    (`floe://<flake>/<port>`) in the topology's endpoint table and
//!    adopts the old incarnation's TCP receivers, so local edges and
//!    remote TCP senders re-resolve and follow the move; routers swap
//!    targets atomically; retired pellets leave the maps; the
//!    versioned graph advances.
//! 5. **Retire + resume**: removed pellets drain their remaining
//!    buffered input through their still-wired outputs, then shut
//!    down and free their cores; everything else resumes.  A retired
//!    pellet's upstream frontier resumes only *after* that drain, so
//!    post-cut traffic on a bypass edge can never overtake the
//!    retired backlog (per-producer FIFO).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::delta::GraphDelta;
use super::plan::{compile, RecomposePlan};
use crate::channel::{
    EndpointAddr, EndpointTable, EndpointTransport, Transport,
};
use crate::container::Container;
use crate::coordinator::{DataflowInner, RepairEvent, Topology};
use crate::error::{FloeError, Result};
use crate::flake::{Flake, FlakeConfig};
use crate::graph::DataflowGraph;
use crate::message::Landmark;

/// Bound on waiting for in-flight compute during the cut-over.
const QUIESCE_TIMEOUT: Duration = Duration::from_secs(30);
/// Bound on draining a retired pellet's buffered input.
const RETIRE_DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// Outcome of one applied delta (also the unit of
/// [`crate::coordinator::RunningDataflow::recompose_history`] and the
/// series measured by
/// `bench_recompose`).
#[derive(Debug, Clone)]
pub struct RecomposeStats {
    /// Graph version after the surgery.
    pub graph_version: u64,
    /// Number of delta ops applied.
    pub ops: usize,
    pub paused: Vec<String>,
    pub spawned: Vec<String>,
    pub removed: Vec<String>,
    pub relocated: Vec<String>,
    /// Pellets re-spawned after their container died
    /// (`DeltaOp::ReplaceFailed`).
    pub replaced: Vec<String>,
    /// Pellets whose endpoint publications were replaced at cut-over
    /// (logical addresses stable, physical resolution moved) — the
    /// live-rebind half of a relocation.
    pub rebound: Vec<String>,
    /// First pause to last resume — the paper's "minimal impact"
    /// number: how long any part of the stream stood still.
    pub downtime_ms: f64,
    /// Time the topology write lock was held (handoff + rewires).
    pub cutover_ms: f64,
}

type PlacedFlake = (String, Arc<Flake>, Arc<Container>);

/// The recomposition engine: one instance per surgery, constructed
/// and serialized by the dataflow's gated `recompose` path (both
/// [`crate::coordinator::RunningDataflow::recompose`] and the failure
/// detector's repair deltas).  Crate-internal so the serialization
/// gate cannot be bypassed.
pub(crate) struct RecomposeEngine<'a> {
    run: &'a DataflowInner,
}

impl<'a> RecomposeEngine<'a> {
    pub(crate) fn new(run: &'a DataflowInner) -> RecomposeEngine<'a> {
        RecomposeEngine { run }
    }

    /// Compile and execute `delta` with the module's
    /// pause → buffer → rewire → resume semantics.
    pub(crate) fn execute(
        &self,
        delta: &GraphDelta,
    ) -> Result<RecomposeStats> {
        execute(self.run, delta)
    }
}

/// Execute a delta against the running dataflow.  Serialized by the
/// caller (`DataflowInner::recompose` holds the gate), so at most one
/// surgery is in flight per dataflow.  Wraps the surgery in a trace
/// span so every recomposition — user-driven, elasticity-driven, or a
/// failure repair — lands in the `GET /trace` timeline with an
/// outcome.
fn execute(
    run: &DataflowInner,
    delta: &GraphDelta,
) -> Result<RecomposeStats> {
    let target = format!("{} op(s)", delta.ops.len());
    let span = crate::telemetry::tracelog().span("recompose", &target);
    match execute_inner(run, delta) {
        Ok(stats) => {
            span.finish(&format!("ok v{}", stats.graph_version));
            Ok(stats)
        }
        Err(e) => {
            span.finish(&format!("error: {e}"));
            Err(e)
        }
    }
}

fn execute_inner(
    run: &DataflowInner,
    delta: &GraphDelta,
) -> Result<RecomposeStats> {
    // Phase 1a: compile against the live topology.
    let (plan, old_graph, old_flakes, old_containers, endpoints) = {
        let topo = run.topo.read().expect("topology poisoned");
        let plan = compile(delta, &topo.graph)?;
        (
            plan,
            topo.graph.clone(),
            topo.flakes.clone(),
            topo.containers.clone(),
            Arc::clone(&topo.endpoints),
        )
    };

    // Phase 1b: spawn new and replacement flakes.  They idle unwired;
    // failures abort before the stream is touched.  A TCP-fed flake is
    // as relocatable as any other: transport endpoints are logical
    // (`floe://<flake>/<port>`), so the cut-over below republishes the
    // moved flake's physical resolution and every sender — local edge
    // or remote TCP peer — re-resolves and follows.
    let spawned = spawn_new_flakes(run, &plan)?;
    let replacements = match spawn_replacements(
        run,
        &plan,
        &old_flakes,
        &old_containers,
        &endpoints,
    ) {
        Ok(r) => r,
        Err(e) => {
            teardown(&spawned);
            return Err(e);
        }
    };

    // Phase 2: pause + quiesce the frontier, strictly upstream-first.
    // Each member is quiesced while everything downstream of it still
    // runs, so an in-flight push into a (possibly full) downstream
    // queue always completes — pausing the whole set at once could
    // leave an upstream worker blocked against a paused neighbour.
    let t_pause = Instant::now();
    let mut ordered: Vec<String> = old_graph
        .wiring_order()
        .unwrap_or_default()
        .into_iter()
        .rev() // wiring order is downstream-first; pause upstream-first
        .filter(|id| plan.pause_set.contains(id))
        .collect();
    for id in &plan.pause_set {
        if !ordered.contains(id) {
            ordered.push(id.clone());
        }
    }
    let paused: Vec<(String, Arc<Flake>)> = ordered
        .iter()
        .filter_map(|id| {
            old_flakes.get(id).map(|f| (id.clone(), Arc::clone(f)))
        })
        .collect();
    for (id, f) in &paused {
        if let Err(e) = f.quiesce(QUIESCE_TIMEOUT) {
            crate::log_warn!("recompose: quiesce of '{id}' failed: {e}");
            for (_, f) in &paused {
                f.resume();
            }
            teardown(&spawned);
            teardown(&replacements);
            return Err(e);
        }
    }

    // Phase 3: landmark the cut on every source whose wiring changes,
    // while the old wiring is still in place.
    let version = plan.new_graph.version;
    for id in plan.rewire.iter().chain(plan.relocate.iter()) {
        if let Some(f) = old_flakes.get(id) {
            f.emit_landmark(Landmark::Recompose { version });
        }
    }

    // Phase 4: cut over under the topology write lock.  On any error
    // the maps are rolled back to the pre-surgery topology (the graph
    // swap is the last step, so the old graph is still in place), the
    // frontier resumes and the spawned flakes are torn down — a failed
    // cut-over degrades to a returned error, never a wedged dataflow.
    // The realistic failure is a handoff quiesce timeout; the rewire
    // steps are validated against the new graph and cannot miss.
    let quiesce_nanos = t_pause.elapsed().as_nanos() as u64;
    let t_cut = Instant::now();
    let mut retired: Vec<PlacedFlake> = Vec::new();
    let mut displaced: Vec<PlacedFlake> = Vec::new();
    let mut failed: Vec<PlacedFlake> = Vec::new();
    let mut repairs: Vec<RepairEvent> = Vec::new();
    {
        let mut topo = run.topo.write().expect("topology poisoned");
        let result = cut_over(
            run,
            &mut topo,
            &plan,
            &old_graph,
            &spawned,
            &replacements,
            &mut retired,
            &mut displaced,
            &mut failed,
            &mut repairs,
        );
        if let Err(e) = result {
            // Dead husks re-enter the maps unchanged and their (stale,
            // closed-queue) endpoint publications are restored, so the
            // dataflow is exactly as broken as before the attempt and
            // the failure detector simply retries next tick.
            for (id, husk, husk_c) in &failed {
                topo.flakes.insert(id.clone(), Arc::clone(husk));
                topo.containers
                    .insert(id.clone(), Arc::clone(husk_c));
                husk.publish_endpoints(&topo.endpoints);
            }
            for (id, old, old_c) in &displaced {
                topo.flakes.insert(id.clone(), Arc::clone(old));
                topo.containers.insert(id.clone(), Arc::clone(old_c));
                // Restore the old incarnation's endpoint publication
                // (and its receivers, if the transfer already
                // happened) so senders resolve it again; the torn-down
                // replacement's stale token can no longer touch the
                // entry.
                if let Some((_, repl, _)) =
                    replacements.iter().find(|(r, _, _)| r == id)
                {
                    old.adopt_tcp_receivers(repl.take_tcp_receivers());
                }
                old.publish_endpoints(&topo.endpoints);
            }
            for (id, f, c) in &retired {
                topo.flakes.insert(id.clone(), Arc::clone(f));
                topo.containers.insert(id.clone(), Arc::clone(c));
            }
            for (id, _, _) in &spawned {
                topo.flakes.remove(id);
                topo.containers.remove(id);
            }
            drop(topo);
            for (_, f) in &paused {
                f.resume();
            }
            teardown(&spawned);
            teardown(&replacements);
            return Err(e);
        }
    }
    let cutover_nanos = t_cut.elapsed().as_nanos() as u64;
    let cutover_ms = cutover_nanos as f64 / 1e6;
    let t_resume = Instant::now();

    // Phase 5: resume order is FIFO-critical.  A retired pellet's
    // upstream frontier must stay paused until the pellet's buffered
    // backlog has drained downstream: resuming it earlier would let
    // post-cut traffic on a bypass edge (e.g. remove 'mid' + add
    // head->tail) overtake the backlog still sitting in the retired
    // pellet.  Survivors that do not feed a retired pellet resume
    // immediately, so retire drains never wait on a paused sink.
    let retire_frontier: Vec<String> = plan
        .remove
        .iter()
        .flat_map(|id| {
            old_graph.edges_into(id).map(|e| e.from_pellet.clone())
        })
        .collect();
    let survivor = |id: &String| {
        !plan.remove.contains(id) && !plan.relocate.contains(id)
    };
    // 5a: survivors outside the retire frontier.
    for (id, f) in &paused {
        if survivor(id) && !retire_frontier.contains(id) {
            f.resume();
        }
    }
    // 5b: retired pellets resume and drain, upstream-first.
    sort_by_wiring(&mut retired, &old_graph);
    for (_, f, _) in &retired {
        f.resume();
    }
    for (id, f, _) in &retired {
        if !f.drain(RETIRE_DRAIN_TIMEOUT) {
            crate::log_warn!(
                "recompose: retired pellet '{id}' did not drain in time"
            );
        }
    }
    // 5c: the retire frontier rejoins the stream.
    for (id, f) in &paused {
        if survivor(id) && retire_frontier.contains(id) {
            f.resume();
        }
    }
    let downtime_nanos = t_pause.elapsed().as_nanos() as u64;
    let downtime_ms = downtime_nanos as f64 / 1e6;
    // 5d: tear the retired flakes down (a second, normally-instant
    // drain covers backlog that was still moving when 5b timed out).
    for (id, f, c) in &retired {
        f.drain(RETIRE_DRAIN_TIMEOUT);
        if let Err(e) = c.remove_flake(id) {
            crate::log_warn!("recompose: removing '{id}': {e}");
        }
    }
    // 5e: displaced flakes are empty husks (queues drained into the
    // replacement); free their cores.
    for (id, _, c) in &displaced {
        if let Err(e) = c.remove_flake(id) {
            crate::log_warn!("recompose: removing displaced '{id}': {e}");
        }
    }
    // 5f: dead husks leave their (dead) container's records; the
    // detector evicts the container itself afterwards.  Their repair
    // events become visible only now, with the repair fully applied.
    for (id, _, c) in &failed {
        if let Err(e) = c.remove_flake(id) {
            crate::log_warn!("recompose: removing failed '{id}': {e}");
        }
    }
    for ev in repairs {
        run.record_repair(ev);
    }
    // Checkpoints of retired pellets must not outlive them: a later
    // delta re-adding the id would otherwise restore stale state.
    if !plan.remove.is_empty() {
        let mut store =
            run.checkpoints.lock().expect("checkpoints poisoned");
        for id in &plan.remove {
            store.remove(id);
        }
    }

    // Per-phase duration histograms + relocation audit events.
    crate::telemetry::ctr_recompose().inc();
    crate::telemetry::hist_recompose_phase("quiesce")
        .record(quiesce_nanos);
    crate::telemetry::hist_recompose_phase("cutover")
        .record(cutover_nanos);
    crate::telemetry::hist_recompose_phase("resume")
        .record(t_resume.elapsed().as_nanos() as u64);
    crate::telemetry::hist_recompose_phase("downtime")
        .record(downtime_nanos);
    for id in plan.relocate.iter() {
        crate::telemetry::tracelog().instant("relocate", id, "ok");
    }
    for id in plan.replace.iter() {
        crate::telemetry::tracelog().instant("replace", id, "ok");
    }

    crate::log_info!(
        "recompose: v{} applied ({} ops, {} paused) in {:.2} ms \
         (cut-over {:.2} ms)",
        version,
        delta.ops.len(),
        paused.len(),
        downtime_ms,
        cutover_ms
    );
    Ok(RecomposeStats {
        graph_version: version,
        ops: delta.ops.len(),
        paused: plan.pause_set.clone(),
        spawned: plan.spawn.clone(),
        removed: plan.remove.clone(),
        relocated: plan.relocate.clone(),
        replaced: plan.replace.clone(),
        rebound: plan.rebind.clone(),
        downtime_ms,
        cutover_ms,
    })
}

/// The write-lock body of a surgery: map swaps, wiring, the
/// relocation handoff, and the failure-repair restore.  Mutations are
/// recorded in `retired` / `displaced` / `failed` so the caller can
/// roll the maps back on error.
#[allow(clippy::too_many_arguments)]
fn cut_over(
    run: &DataflowInner,
    topo: &mut Topology,
    plan: &RecomposePlan,
    old_graph: &DataflowGraph,
    spawned: &[PlacedFlake],
    replacements: &[PlacedFlake],
    retired: &mut Vec<PlacedFlake>,
    displaced: &mut Vec<PlacedFlake>,
    failed: &mut Vec<PlacedFlake>,
    repairs: &mut Vec<RepairEvent>,
) -> Result<()> {
    // New and replacement flakes join the resolution map first so
    // every rewire below can target them.
    for (id, f, c) in spawned.iter().chain(replacements.iter()) {
        if let Some(old) = topo.flakes.get(id) {
            // Replacement: remember the displaced (or dead)
            // incarnation.
            let rec = (
                id.clone(),
                Arc::clone(old),
                Arc::clone(&topo.containers[id]),
            );
            if plan.replace.contains(id) {
                failed.push(rec);
            } else {
                displaced.push(rec);
            }
        }
        topo.flakes.insert(id.clone(), Arc::clone(f));
        topo.containers.insert(id.clone(), Arc::clone(c));
    }
    // Brand-new pellets publish their endpoints now: nothing sends to
    // them until the frontier resumes, but the addresses must resolve
    // the moment the rewired edges go live.
    for (_, f, _) in spawned.iter() {
        f.publish_endpoints(&topo.endpoints);
    }
    // Wire the newcomers' outputs per the successor graph.
    for (id, f, _) in spawned.iter().chain(replacements.iter()) {
        rewire_flake(f, id, &plan.new_graph, topo)?;
    }
    // The rebind step (plan.rebind): state + buffered-input handoff
    // for relocations (the old flake is already quiesced, so this is
    // capture + replay), then the replacement *republishes* the moved
    // flake's logical endpoints — same `floe://` addresses, physical
    // resolution now at the new container — and adopts the old
    // incarnation's TCP receivers so remote senders that have not yet
    // re-resolved keep a live socket whose deliveries land here.
    // Order matters for per-producer FIFO: a remote delivery that
    // raced the handoff retries against the table and can only land
    // *after* this republication, i.e. after the captured backlog was
    // replayed.
    for (id, old, _) in displaced.iter() {
        let cp = old.handoff()?;
        topo.flakes[id].restore(&cp)?;
        topo.flakes[id].publish_endpoints(&topo.endpoints);
        topo.flakes[id].adopt_tcp_receivers(old.take_tcp_receivers());
    }
    // The repair restore (plan.replace): no handoff — the dead
    // incarnation's memory is gone, so the replacement resumes from
    // the pellet's last periodic checkpoint (fresh state when none
    // was ever captured) and the checkpoint's queued input is
    // replayed into it.  Publication comes *after* the restore:
    // upstream senders retrying against the stale entry land only
    // once the replayed backlog is in the queues, preserving
    // per-producer order, and the restored dedup watermarks drop
    // whatever at-least-once redelivery repeats from before the
    // capture.  No receiver adoption — the dead host's sockets died
    // with it; remote senders reconnect through the republished
    // endpoint.
    for (id, old, husk_c) in failed.iter() {
        // Fence the old incarnation first.  After a genuine crash
        // this is an idempotent no-op, but a container declared dead
        // across a network *partition* is still running — without the
        // fence its flakes would keep processing alongside the
        // replacement (split-brain double-processing).
        old.crash();
        let cp = {
            let store =
                run.checkpoints.lock().expect("checkpoints poisoned");
            store.get(id).cloned()
        };
        let replayed = match &cp {
            Some(cp) => {
                topo.flakes[id].restore(cp)?;
                cp.queued.values().map(Vec::len).sum()
            }
            None => 0,
        };
        topo.flakes[id].publish_endpoints(&topo.endpoints);
        let to_container = replacements
            .iter()
            .find(|(r, _, _)| r == id)
            .map(|(_, _, c)| c.id.clone())
            .unwrap_or_default();
        repairs.push(RepairEvent {
            flake: id.clone(),
            from_container: husk_c.id.clone(),
            to_container,
            restored_from_checkpoint: cp.is_some(),
            replayed,
        });
    }
    // Atomic target swaps on the pre-existing frontier.
    for id in &plan.rewire {
        let f = Arc::clone(&topo.flakes[id]);
        rewire_flake(&f, id, &plan.new_graph, topo)?;
    }
    // Retired pellets keep their *old* edges but re-resolved against
    // the updated map, so their drain still lands on the current
    // incarnation of each downstream sink.
    for id in &plan.remove {
        let f = Arc::clone(&topo.flakes[id]);
        rewire_flake(&f, id, old_graph, topo)?;
    }
    for id in &plan.remove {
        let f = topo.flakes.remove(id).expect("validated removal");
        let c = topo.containers.remove(id).expect("validated removal");
        retired.push((id.clone(), f, c));
    }
    topo.graph = plan.new_graph.clone();
    Ok(())
}

/// Spawn the delta's brand-new pellets (AddPellet / InsertOnEdge).
fn spawn_new_flakes(
    run: &DataflowInner,
    plan: &RecomposePlan,
) -> Result<Vec<PlacedFlake>> {
    let mut out = Vec::new();
    for id in &plan.spawn {
        let spec = plan
            .new_graph
            .pellet(id)
            .ok_or_else(|| {
                FloeError::Graph(format!("plan: missing pellet '{id}'"))
            })?
            .clone();
        let factory = match run.registry.resolve(&spec.class) {
            Ok(f) => f,
            Err(e) => {
                teardown(&out);
                return Err(e);
            }
        };
        let mut cfg = FlakeConfig::from_spec(&spec);
        run.tuning.apply(&mut cfg);
        let placed = run
            .manager
            .allocate(cfg.cores)
            .and_then(|c| c.spawn_flake(cfg, factory).map(|f| (f, c)));
        match placed {
            Ok((f, c)) => out.push((id.clone(), f, c)),
            Err(e) => {
                teardown(&out);
                return Err(e);
            }
        }
    }
    Ok(out)
}

/// Spawn replacement flakes — for relocations *and* failure repairs —
/// on a *different* container, cloning the (possibly dead) original's
/// config and its live (possibly updated) pellet factory.  A TCP-fed
/// original gets a fresh ingress endpoint bound on the replacement up
/// front (failures still abort with zero side effects); the endpoint
/// is published at cut-over.  For repairs the husk's config, factory,
/// and endpoint record all survive the crash by design (see
/// [`crate::container::Container::kill`]); its *state* does not, which
/// is what the checkpoint restore at cut-over is for.
fn spawn_replacements(
    run: &DataflowInner,
    plan: &RecomposePlan,
    old_flakes: &HashMap<String, Arc<Flake>>,
    old_containers: &HashMap<String, Arc<Container>>,
    endpoints: &Arc<EndpointTable>,
) -> Result<Vec<PlacedFlake>> {
    let mut out = Vec::new();
    for id in plan.relocate.iter().chain(plan.replace.iter()) {
        let (old, old_c) = match (
            old_flakes.get(id),
            old_containers.get(id),
        ) {
            (Some(f), Some(c)) => (f, c),
            _ => {
                teardown(&out);
                return Err(FloeError::Graph(format!(
                    "recompose: no flake '{id}' to replace"
                )));
            }
        };
        let cfg = old.config();
        let factory = old.current_factory();
        let serve_tcp = old.tcp_endpoint().is_some();
        let placed = run
            .manager
            .allocate_avoiding(cfg.cores, &old_c.id)
            .and_then(|c| c.spawn_flake(cfg, factory).map(|f| (f, c)));
        match placed {
            Ok((f, c)) => {
                if serve_tcp {
                    if let Err(e) = f.serve_tcp_in(0, endpoints) {
                        let _ = c.remove_flake(id);
                        teardown(&out);
                        return Err(e);
                    }
                }
                out.push((id.clone(), f, c));
            }
            Err(e) => {
                teardown(&out);
                return Err(e);
            }
        }
    }
    Ok(out)
}

/// Atomically set every output port of `flake` to the targets `graph`
/// prescribes.  Targets are logical endpoint handles resolved through
/// the topology's table at send time; the sink flake and port are
/// still validated eagerly against the live flake map so a bad edge
/// fails the surgery, not the stream.
fn rewire_flake(
    flake: &Arc<Flake>,
    id: &str,
    graph: &DataflowGraph,
    topo: &Topology,
) -> Result<()> {
    for port in flake.output_ports() {
        let mut targets: Vec<Arc<dyn Transport>> = Vec::new();
        for edge in graph.edges_from(id, &port) {
            let sink =
                topo.flakes.get(&edge.to_pellet).ok_or_else(|| {
                    FloeError::Graph(format!(
                        "recompose: edge target '{}' has no flake",
                        edge.to_pellet
                    ))
                })?;
            sink.input_queue(&edge.to_port)?; // validate the port
            targets.push(Arc::new(EndpointTransport::new(
                Arc::clone(&topo.endpoints),
                EndpointAddr::new(
                    edge.to_pellet.clone(),
                    edge.to_port.clone(),
                ),
                format!(
                    "{}.{} -> {}.{}",
                    edge.from_pellet,
                    edge.from_port,
                    edge.to_pellet,
                    edge.to_port
                ),
            )));
        }
        flake.replace_output_targets(&port, targets)?;
    }
    Ok(())
}

/// Upstream-first order for retiring pellets, so a retired pellet's
/// drain can still deliver into a downstream pellet retired by the
/// same delta.
fn sort_by_wiring(retired: &mut [PlacedFlake], graph: &DataflowGraph) {
    if let Ok(order) = graph.wiring_order() {
        // wiring_order is downstream-first; retire upstream-first.
        let pos = |id: &str| {
            order.iter().position(|x| x == id).unwrap_or(0)
        };
        retired.sort_by(|a, b| pos(&b.0).cmp(&pos(&a.0)));
    }
}

/// Best-effort rollback of flakes spawned before an aborted cut-over.
fn teardown(placed: &[PlacedFlake]) {
    for (id, _, c) in placed {
        if let Err(e) = c.remove_flake(id) {
            crate::log_warn!("recompose: rollback of '{id}': {e}");
        }
    }
}
