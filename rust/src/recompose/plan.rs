//! Plan compilation: a validated [`GraphDelta`] becomes a
//! [`RecomposePlan`] — the successor graph plus the *minimal pause
//! set*, computed from the delta's upstream frontier.
//!
//! Only pellets whose **output wiring changes** (the source pellet of
//! every added/removed/retargeted/spliced edge, and every upstream
//! neighbour of a removed or relocated pellet) plus the
//! removed/relocated pellets themselves are paused.  The rest of the
//! dataflow keeps running through the surgery; messages heading into
//! the paused frontier simply buffer in its input queues under the
//! normal backpressure contract.

use std::collections::BTreeSet;

use super::delta::{DeltaOp, GraphDelta};
use crate::error::{FloeError, Result};
use crate::graph::DataflowGraph;

/// Compiled surgery plan (see module docs for the pause-set rules).
#[derive(Debug, Clone)]
pub struct RecomposePlan {
    /// The successor topology (version = live version + 1).
    pub new_graph: DataflowGraph,
    /// Pellets paused and quiesced for the cut-over, sorted.
    pub pause_set: Vec<String>,
    /// Pre-existing pellets whose routers are atomically re-targeted.
    pub rewire: Vec<String>,
    /// Pellets spawned by this delta (AddPellet / InsertOnEdge).
    pub spawn: Vec<String>,
    /// Pellets retired by this delta.
    pub remove: Vec<String>,
    /// Pellets whose flakes move to a different container.
    pub relocate: Vec<String>,
    /// Pellets re-spawned after their container died.  Never paused
    /// or quiesced (the dead node cannot ack anything) and never in
    /// the rewire set: upstream routers keep their logical targets
    /// and re-resolve once the replacement republishes at cut-over.
    pub replace: Vec<String>,
    /// The rebind step of the pause frontier: pellets whose endpoint
    /// publications are replaced at cut-over.  Their logical addresses
    /// stay stable; the engine republishes the physical resolution at
    /// the new container so every sender — including remote TCP peers
    /// — re-resolves after the move.  Today every relocation rebinds
    /// (local queues republish too), so this equals `relocate` by
    /// construction; it is a separate step so future deltas that
    /// rebind without relocating (e.g. re-homing an ingress endpoint
    /// in place) slot in without changing the engine's phase order.
    pub rebind: Vec<String>,
}

/// Compile `delta` against the live graph.
pub fn compile(
    delta: &GraphDelta,
    graph: &DataflowGraph,
) -> Result<RecomposePlan> {
    let new_graph = delta.apply_to(graph)?;
    let mut pause: BTreeSet<String> = BTreeSet::new();
    let mut rewire: BTreeSet<String> = BTreeSet::new();
    let mut spawn: Vec<String> = Vec::new();
    let mut remove: Vec<String> = Vec::new();
    let mut relocate: Vec<String> = Vec::new();
    let mut replace: Vec<String> = Vec::new();
    for op in &delta.ops {
        match op {
            DeltaOp::AddPellet { spec } => spawn.push(spec.id.clone()),
            DeltaOp::InsertOnEdge { edge, spec, .. } => {
                spawn.push(spec.id.clone());
                pause.insert(edge.from_pellet.clone());
                rewire.insert(edge.from_pellet.clone());
            }
            DeltaOp::AddEdge { edge }
            | DeltaOp::RemoveEdge { edge }
            | DeltaOp::RetargetEdge { edge, .. } => {
                pause.insert(edge.from_pellet.clone());
                rewire.insert(edge.from_pellet.clone());
            }
            DeltaOp::RemovePellet { id } => {
                for e in graph.edges_into(id) {
                    pause.insert(e.from_pellet.clone());
                    rewire.insert(e.from_pellet.clone());
                }
                pause.insert(id.clone());
                remove.push(id.clone());
            }
            DeltaOp::RelocateFlake { id } => {
                for e in graph.edges_into(id) {
                    pause.insert(e.from_pellet.clone());
                    rewire.insert(e.from_pellet.clone());
                }
                pause.insert(id.clone());
                relocate.push(id.clone());
            }
            DeltaOp::ReplaceFailed { id } => replace.push(id.clone()),
        }
    }
    relocate.sort();
    relocate.dedup();
    remove.sort();
    remove.dedup();
    replace.sort();
    replace.dedup();
    // Repair deltas stand alone: a `ReplaceFailed` runs with an empty
    // pause set (pausing the dead pellet's upstream would wedge
    // senders against a sink that can never drain), which is only
    // sound when no other op needs that frontier quiesced.  A whole
    // container's worth of replacements may batch together.
    if !replace.is_empty()
        && delta
            .ops
            .iter()
            .any(|op| !matches!(op, DeltaOp::ReplaceFailed { .. }))
    {
        return Err(FloeError::Graph(
            "ReplaceFailed cannot mix with other ops; \
             repair deltas stand alone"
                .into(),
        ));
    }
    // One relocation per delta: a handoff can only fail *before* it
    // mutates anything (its quiesce), so with a single relocation the
    // engine's rollback is always sound.  A second handoff failing
    // after the first succeeded would strand the first pellet's
    // captured backlog in a replacement the rollback tears down.
    if relocate.len() > 1 {
        return Err(FloeError::Graph(
            "one relocation per delta; split into separate deltas"
                .into(),
        ));
    }
    if relocate.iter().any(|id| remove.contains(id)) {
        return Err(FloeError::Graph(
            "delta both removes and relocates a pellet".into(),
        ));
    }
    // Removing and re-adding one id in a single delta would retire the
    // freshly spawned flake (the graph would then claim a pellet with
    // no live flake); relocating a same-delta spawn is equally
    // meaningless.  Split such edits across two deltas.
    if spawn.iter().any(|id| remove.contains(id)) {
        return Err(FloeError::Graph(
            "delta both spawns and removes a pellet".into(),
        ));
    }
    if spawn.iter().any(|id| relocate.contains(id)) {
        return Err(FloeError::Graph(
            "delta both spawns and relocates a pellet".into(),
        ));
    }
    // A pellet spawned by this same delta is born paused-free and gets
    // wired from scratch; only pre-existing pellets pause or rewire.
    pause.retain(|id| graph.pellet(id).is_some());
    rewire.retain(|id| {
        graph.pellet(id).is_some() && !remove.contains(id)
    });
    // A relocation replays its captured backlog through the
    // replacement while the topology write lock is held; if any
    // pellet *reachable downstream* of the relocated one is paused by
    // this same delta, the replay can cascade into that paused queue
    // and block forever under the lock.  Reject the combination —
    // split it across two deltas.  (This also rejects relocating a
    // pellet on a cycle whose loop passes through its own paused
    // upstream frontier: the replay could wedge against it the same
    // way.)
    for id in &relocate {
        let mut frontier = vec![id.clone()];
        let mut reachable: BTreeSet<String> = BTreeSet::new();
        while let Some(cur) = frontier.pop() {
            for e in
                graph.edges.iter().filter(|e| e.from_pellet == cur)
            {
                if reachable.insert(e.to_pellet.clone()) {
                    frontier.push(e.to_pellet.clone());
                }
            }
        }
        if let Some(blocked) =
            reachable.iter().find(|p| pause.contains(*p))
        {
            return Err(FloeError::Graph(format!(
                "delta relocates '{id}' while pausing downstream \
                 '{blocked}'; split into two deltas"
            )));
        }
    }
    let mut rebind: Vec<String> =
        relocate.iter().chain(replace.iter()).cloned().collect();
    rebind.sort();
    Ok(RecomposePlan {
        new_graph,
        pause_set: pause.into_iter().collect(),
        rewire: rewire.into_iter().collect(),
        spawn,
        remove,
        relocate,
        replace,
        rebind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, SplitMode};

    fn diamond() -> DataflowGraph {
        let mut g = GraphBuilder::new("d");
        g.pellet("src", "C").out_port("out", SplitMode::RoundRobin);
        g.pellet("l", "C")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        g.pellet("r", "C")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        g.pellet("sink", "C").in_port("in");
        g.edge("src", "out", "l", "in");
        g.edge("src", "out", "r", "in");
        g.edge("l", "out", "sink", "in");
        g.edge("r", "out", "sink", "in");
        g.build().unwrap()
    }

    #[test]
    fn pause_set_is_upstream_frontier_only() {
        let g = diamond();
        // Removing 'r' pauses its upstream (src) and r itself; the
        // untouched l/sink branch keeps running.
        let mut d = GraphDelta::against(&g);
        d.remove_pellet("r");
        let plan = compile(&d, &g).unwrap();
        assert_eq!(plan.pause_set, vec!["r", "src"]);
        assert_eq!(plan.rewire, vec!["src"]);
        assert_eq!(plan.remove, vec!["r"]);
        assert!(plan.spawn.is_empty());
    }

    #[test]
    fn relocation_pauses_self_and_upstream() {
        let g = diamond();
        let mut d = GraphDelta::against(&g);
        d.relocate_flake("l");
        let plan = compile(&d, &g).unwrap();
        assert_eq!(plan.pause_set, vec!["l", "src"]);
        assert_eq!(plan.rewire, vec!["src"]);
        assert_eq!(plan.relocate, vec!["l"]);
        assert_eq!(plan.rebind, vec!["l"], "relocation implies rebind");
    }

    #[test]
    fn replace_failed_pauses_nothing() {
        let g = diamond();
        let mut d = GraphDelta::against(&g);
        d.replace_failed("l").replace_failed("r");
        let plan = compile(&d, &g).unwrap();
        assert!(plan.pause_set.is_empty(), "{:?}", plan.pause_set);
        assert!(plan.rewire.is_empty());
        assert_eq!(plan.replace, vec!["l", "r"]);
        assert_eq!(plan.rebind, vec!["l", "r"]);
        assert_eq!(plan.new_graph.version, g.version + 1);
    }

    #[test]
    fn replace_failed_mixing_with_other_ops_rejected() {
        let g = diamond();
        let mut d = GraphDelta::against(&g);
        d.replace_failed("l").remove_pellet("r");
        assert!(compile(&d, &g).is_err());
        let mut d = GraphDelta::against(&g);
        d.replace_failed("l").relocate_flake("r");
        assert!(compile(&d, &g).is_err());
        let mut d = GraphDelta::against(&g);
        d.replace_failed("ghost");
        assert!(compile(&d, &g).is_err(), "unknown pellet rejected");
    }

    #[test]
    fn remove_and_relocate_same_pellet_rejected() {
        let g = diamond();
        let mut d = GraphDelta::against(&g);
        d.remove_pellet("r").relocate_flake("r");
        assert!(compile(&d, &g).is_err());
    }

    #[test]
    fn multiple_relocations_rejected() {
        let g = diamond();
        let mut d = GraphDelta::against(&g);
        d.relocate_flake("l").relocate_flake("r");
        assert!(compile(&d, &g).is_err());
    }

    #[test]
    fn relocate_with_paused_downstream_rejected() {
        let g = diamond();
        // Removing 'sink' pauses l/r/sink; relocating 'l' would replay
        // its backlog into the paused 'sink' under the topology lock.
        let mut d = GraphDelta::against(&g);
        d.relocate_flake("l").remove_pellet("sink");
        assert!(compile(&d, &g).is_err());
    }

    #[test]
    fn remove_then_readd_same_id_rejected() {
        let g = diamond();
        let mut tmp = GraphBuilder::new("tmp");
        tmp.pellet("r", "C")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        let mut built = tmp.build().unwrap();
        let spec = built.pellets.remove(0);
        let mut d = GraphDelta::against(&g);
        d.remove_pellet("r")
            .add_pellet(spec)
            .add_edge("src", "out", "r", "in")
            .add_edge("r", "out", "sink", "in");
        assert!(compile(&d, &g).is_err());
    }

    #[test]
    fn edge_to_new_pellet_pauses_only_its_source() {
        let g = diamond();
        let mut spec_g = GraphBuilder::new("tmp");
        spec_g.pellet("tap", "C").in_port("in");
        let spec = spec_g.build().unwrap().pellets.remove(0);
        let mut d = GraphDelta::against(&g);
        d.add_pellet(spec).add_edge("l", "out", "tap", "in");
        let plan = compile(&d, &g).unwrap();
        assert_eq!(plan.pause_set, vec!["l"]);
        assert_eq!(plan.spawn, vec!["tap"]);
    }
}
