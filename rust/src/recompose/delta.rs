//! The surgery grammar: [`GraphDelta`] is a batch of topology edits
//! validated and applied *atomically* against a versioned live
//! [`DataflowGraph`].
//!
//! A delta names the graph version it was computed against
//! (`base_version`); applying it to any other version fails, so two
//! concurrent surgeries are detected instead of silently composed.
//! Application is all-or-nothing: every op is checked while editing a
//! clone, the result is re-validated structurally, and only then does
//! the engine adopt it — a bad delta never leaves the live graph (or
//! the running dataflow) half-edited.

use crate::error::{FloeError, Result};
use crate::graph::{DataflowGraph, EdgeSpec, PelletSpec};

/// One topology edit.
#[derive(Debug, Clone)]
pub enum DeltaOp {
    /// Add a disconnected pellet (wire it with [`DeltaOp::AddEdge`]
    /// ops in the same delta).
    AddPellet { spec: PelletSpec },
    /// Retire a pellet: upstream edges are rewired away, buffered
    /// input drains through its existing outputs, then the flake is
    /// torn down and its cores freed.
    RemovePellet { id: String },
    /// Add an edge between existing (or same-delta-added) pellets.
    AddEdge { edge: EdgeSpec },
    /// Remove an edge; messages already delivered downstream stay.
    RemoveEdge { edge: EdgeSpec },
    /// Splice a new pellet into an existing edge: `A.out -> B.in`
    /// becomes `A.out -> new.in_port` + `new.out_port -> B.in`.
    InsertOnEdge {
        edge: EdgeSpec,
        spec: PelletSpec,
        in_port: String,
        out_port: String,
    },
    /// Point an existing edge at a different sink pellet/port.
    RetargetEdge { edge: EdgeSpec, to_pellet: String, to_port: String },
    /// Move a pellet's flake to a different container, preserving
    /// state, logic version and buffered input (no structural change).
    RelocateFlake { id: String },
    /// Re-spawn a pellet whose container died (no structural change).
    /// Unlike [`DeltaOp::RelocateFlake`] the dead flake is never
    /// paused, quiesced, or handed off — it cannot acknowledge
    /// anything — so the replacement starts from the pellet's last
    /// checkpoint (fresh when none exists) and upstream delivery
    /// retry bridges the repair window.
    ReplaceFailed { id: String },
}

/// A batch of topology edits against one graph version.
#[derive(Debug, Clone)]
pub struct GraphDelta {
    /// Graph version this delta was computed against.
    pub base_version: u64,
    pub ops: Vec<DeltaOp>,
}

impl GraphDelta {
    pub fn new(base_version: u64) -> GraphDelta {
        GraphDelta { base_version, ops: Vec::new() }
    }

    /// A delta against the current version of `graph`.
    pub fn against(graph: &DataflowGraph) -> GraphDelta {
        GraphDelta::new(graph.version)
    }

    pub fn add_pellet(&mut self, spec: PelletSpec) -> &mut Self {
        self.ops.push(DeltaOp::AddPellet { spec });
        self
    }

    pub fn remove_pellet(&mut self, id: &str) -> &mut Self {
        self.ops.push(DeltaOp::RemovePellet { id: id.into() });
        self
    }

    pub fn add_edge(
        &mut self,
        from: &str,
        from_port: &str,
        to: &str,
        to_port: &str,
    ) -> &mut Self {
        self.ops.push(DeltaOp::AddEdge {
            edge: EdgeSpec::new(from, from_port, to, to_port),
        });
        self
    }

    pub fn remove_edge(
        &mut self,
        from: &str,
        from_port: &str,
        to: &str,
        to_port: &str,
    ) -> &mut Self {
        self.ops.push(DeltaOp::RemoveEdge {
            edge: EdgeSpec::new(from, from_port, to, to_port),
        });
        self
    }

    /// Splice `spec` into `edge`, receiving on `in_port` and
    /// re-emitting on `out_port`.
    pub fn insert_on_edge(
        &mut self,
        edge: EdgeSpec,
        spec: PelletSpec,
        in_port: &str,
        out_port: &str,
    ) -> &mut Self {
        self.ops.push(DeltaOp::InsertOnEdge {
            edge,
            spec,
            in_port: in_port.into(),
            out_port: out_port.into(),
        });
        self
    }

    pub fn retarget_edge(
        &mut self,
        edge: EdgeSpec,
        to_pellet: &str,
        to_port: &str,
    ) -> &mut Self {
        self.ops.push(DeltaOp::RetargetEdge {
            edge,
            to_pellet: to_pellet.into(),
            to_port: to_port.into(),
        });
        self
    }

    pub fn relocate_flake(&mut self, id: &str) -> &mut Self {
        self.ops.push(DeltaOp::RelocateFlake { id: id.into() });
        self
    }

    pub fn replace_failed(&mut self, id: &str) -> &mut Self {
        self.ops.push(DeltaOp::ReplaceFailed { id: id.into() });
        self
    }

    /// Apply to a graph, producing the successor topology at
    /// `graph.version + 1`.  All-or-nothing: version mismatch, an
    /// invalid op, or a structurally invalid result graph all fail
    /// without side effects on `graph`.
    pub fn apply_to(&self, graph: &DataflowGraph) -> Result<DataflowGraph> {
        if self.base_version != graph.version {
            return Err(FloeError::Graph(format!(
                "delta computed against graph v{}, live graph is v{}",
                self.base_version, graph.version
            )));
        }
        if self.ops.is_empty() {
            return Err(FloeError::Graph("empty delta".into()));
        }
        let mut g = graph.clone();
        for op in &self.ops {
            apply_op(&mut g, op)?;
        }
        g.version = graph.version + 1;
        g.validate()?;
        Ok(g)
    }
}

fn apply_op(g: &mut DataflowGraph, op: &DeltaOp) -> Result<()> {
    match op {
        DeltaOp::AddPellet { spec } => {
            if g.pellet(&spec.id).is_some() {
                return Err(FloeError::Graph(format!(
                    "delta: pellet '{}' already exists",
                    spec.id
                )));
            }
            g.pellets.push(spec.clone());
        }
        DeltaOp::RemovePellet { id } => {
            let before = g.pellets.len();
            g.pellets.retain(|p| p.id != *id);
            if g.pellets.len() == before {
                return Err(FloeError::Graph(format!(
                    "delta: no pellet '{id}' to remove"
                )));
            }
            g.edges
                .retain(|e| e.from_pellet != *id && e.to_pellet != *id);
        }
        DeltaOp::AddEdge { edge } => {
            if g.edges.contains(edge) {
                return Err(FloeError::Graph(format!(
                    "delta: edge {}.{} -> {}.{} already exists",
                    edge.from_pellet,
                    edge.from_port,
                    edge.to_pellet,
                    edge.to_port
                )));
            }
            g.edges.push(edge.clone());
        }
        DeltaOp::RemoveEdge { edge } => {
            let pos = find_edge(g, edge)?;
            g.edges.remove(pos);
        }
        DeltaOp::InsertOnEdge { edge, spec, in_port, out_port } => {
            if g.pellet(&spec.id).is_some() {
                return Err(FloeError::Graph(format!(
                    "delta: pellet '{}' already exists",
                    spec.id
                )));
            }
            if spec.in_port(in_port).is_none() {
                return Err(FloeError::Graph(format!(
                    "delta: insert pellet '{}' has no in port '{in_port}'",
                    spec.id
                )));
            }
            if spec.out_port(out_port).is_none() {
                return Err(FloeError::Graph(format!(
                    "delta: insert pellet '{}' has no out port '{out_port}'",
                    spec.id
                )));
            }
            let pos = find_edge(g, edge)?;
            g.edges.remove(pos);
            g.edges.push(EdgeSpec::new(
                &edge.from_pellet,
                &edge.from_port,
                &spec.id,
                in_port,
            ));
            g.edges.push(EdgeSpec::new(
                &spec.id,
                out_port,
                &edge.to_pellet,
                &edge.to_port,
            ));
            g.pellets.push(spec.clone());
        }
        DeltaOp::RetargetEdge { edge, to_pellet, to_port } => {
            let pos = find_edge(g, edge)?;
            g.edges[pos].to_pellet = to_pellet.clone();
            g.edges[pos].to_port = to_port.clone();
        }
        DeltaOp::RelocateFlake { id } => {
            if g.pellet(id).is_none() {
                return Err(FloeError::Graph(format!(
                    "delta: no pellet '{id}' to relocate"
                )));
            }
        }
        DeltaOp::ReplaceFailed { id } => {
            if g.pellet(id).is_none() {
                return Err(FloeError::Graph(format!(
                    "delta: no pellet '{id}' to replace"
                )));
            }
        }
    }
    Ok(())
}

fn find_edge(g: &DataflowGraph, edge: &EdgeSpec) -> Result<usize> {
    g.edges.iter().position(|e| e == edge).ok_or_else(|| {
        FloeError::Graph(format!(
            "delta: no edge {}.{} -> {}.{}",
            edge.from_pellet, edge.from_port, edge.to_pellet, edge.to_port
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, SplitMode};

    fn linear() -> DataflowGraph {
        let mut g = GraphBuilder::new("lin");
        g.pellet("a", "C").out_port("out", SplitMode::RoundRobin);
        g.pellet("b", "C")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        g.pellet("c", "C").in_port("in");
        g.edge("a", "out", "b", "in");
        g.edge("b", "out", "c", "in");
        g.build().unwrap()
    }

    fn filter_spec(id: &str) -> PelletSpec {
        let mut g = GraphBuilder::new("tmp");
        g.pellet(id, "C")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        let mut built = g.build().unwrap();
        built.pellets.remove(0)
    }

    #[test]
    fn version_mismatch_rejected() {
        let g = linear();
        let mut d = GraphDelta::new(g.version + 1);
        d.remove_edge("a", "out", "b", "in");
        assert!(d.apply_to(&g).is_err());
        assert!(GraphDelta::against(&g).apply_to(&g).is_err()); // empty
    }

    #[test]
    fn insert_on_edge_rewires_both_sides() {
        let g = linear();
        let mut d = GraphDelta::against(&g);
        d.insert_on_edge(
            EdgeSpec::new("a", "out", "b", "in"),
            filter_spec("f"),
            "in",
            "out",
        );
        let g2 = d.apply_to(&g).unwrap();
        assert_eq!(g2.version, g.version + 1);
        assert!(g2.pellet("f").is_some());
        assert_eq!(g2.edges_from("a", "out").count(), 1);
        assert_eq!(
            g2.edges_from("a", "out").next().unwrap().to_pellet,
            "f"
        );
        assert_eq!(
            g2.edges_from("f", "out").next().unwrap().to_pellet,
            "b"
        );
        // Original untouched.
        assert!(g.pellet("f").is_none());
    }

    #[test]
    fn remove_pellet_drops_its_edges() {
        let g = linear();
        let mut d = GraphDelta::against(&g);
        d.remove_pellet("b").add_edge("a", "out", "c", "in");
        let g2 = d.apply_to(&g).unwrap();
        assert!(g2.pellet("b").is_none());
        assert_eq!(g2.edges.len(), 1);
        assert_eq!(g2.edges[0].to_pellet, "c");
    }

    #[test]
    fn invalid_result_rejected_atomically() {
        let g = linear();
        // Removing b leaves c orphaned (fine) but removing b while
        // keeping its edges is impossible; instead check a dangling
        // add_edge is rejected by the post-apply validation.
        let mut d = GraphDelta::against(&g);
        d.add_edge("a", "out", "ghost", "in");
        assert!(d.apply_to(&g).is_err());
        let mut d = GraphDelta::against(&g);
        d.remove_edge("a", "out", "ghost", "in");
        assert!(d.apply_to(&g).is_err());
        let mut d = GraphDelta::against(&g);
        d.relocate_flake("ghost");
        assert!(d.apply_to(&g).is_err());
    }

    #[test]
    fn retarget_edge_moves_sink() {
        let g = linear();
        let mut d = GraphDelta::against(&g);
        d.retarget_edge(EdgeSpec::new("a", "out", "b", "in"), "c", "in")
            .remove_edge("b", "out", "c", "in");
        let g2 = d.apply_to(&g).unwrap();
        assert_eq!(
            g2.edges_from("a", "out").next().unwrap().to_pellet,
            "c"
        );
    }
}
