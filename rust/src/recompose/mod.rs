//! Live graph surgery (§II-B "dynamic recomposition at runtime with
//! minimal impact on the execution"): restructure a **running**
//! dataflow — add/remove pellets and edges, splice a pellet into a
//! live edge, retarget an edge, migrate a flake to another container —
//! without stopping the stream and without losing a message.
//!
//! # Design notes
//!
//! The subsystem is three layers, each independently testable:
//!
//! * [`GraphDelta`] (`delta.rs`) — the surgery grammar.  A delta is a
//!   batch of [`DeltaOp`]s pinned to the graph version it was computed
//!   against; [`GraphDelta::apply_to`] is a pure function producing
//!   the successor [`crate::graph::DataflowGraph`] (version + 1) or an
//!   error, never a half-edited graph.  Optimistic concurrency: a
//!   delta raced by another surgery fails its version check and is
//!   recomputed by the caller against the new topology.
//! * [`RecomposePlan`] (`plan.rs`) — compilation.  From the delta's
//!   *upstream frontier* it derives the **minimal pause set**: only
//!   pellets whose output wiring changes (sources of edited edges,
//!   upstream neighbours of removed/relocated pellets) and the
//!   removed/relocated pellets themselves stand still; every other
//!   pellet keeps streaming through the surgery.
//! * the `RecomposeEngine` executor (`engine.rs`) — execution, with
//!   pause → buffer-at-upstream → rewire → resume semantics:
//!
//!   1. spawn new/replacement flakes unwired (failures abort with the
//!      stream untouched);
//!   2. pause + quiesce the frontier — arrivals buffer in the paused
//!      input queues under the normal backpressure bound, so
//!      producers slow down rather than drop;
//!   3. broadcast [`crate::message::Landmark::Recompose`] on every
//!      rewired source, separating pre- from post-surgery streams for
//!      downstream consumers (per producer, and best-effort: a full
//!      edge drops the marker rather than wedging the engine — it is
//!      a hint, not a barrier; the loss/FIFO guarantees below never
//!      depend on it);
//!   4. cut over under the topology write lock: relocations hand
//!      state + buffered input to their replacement through
//!      [`crate::flake::FlakeCheckpoint`] (`handoff` closes the old
//!      queues behind the capture, so a racing injector re-resolves
//!      the replacement instead of stranding a message), and routers
//!      swap their target sets atomically
//!      ([`crate::flake::OutputRouter::replace_targets`]);
//!   5. retired pellets drain their buffered input through their old
//!      (re-resolved) edges upstream-first, then shut down and free
//!      their cores; everyone else resumes — the retired pellets'
//!      own upstream frontier last, so bypass-edge traffic cannot
//!      overtake the drained backlog (per-producer FIFO).
//!
//! **Invariants** (exercised by `tests/test_recompose.rs` property
//! tests): zero message loss across insert-on-edge, remove-pellet and
//! flake relocation under concurrent injection; per-producer FIFO is
//! preserved (a producer's retried message lands *after* its earlier
//! messages were replayed into the replacement, never before).
//!
//! **Measured**: `cargo bench --bench bench_recompose` reports the
//! pause-to-resume downtime and write-lock cut-over window per
//! surgery class into `BENCH_recompose.json`, so "minimal impact" is
//! a tracked number rather than a claim.
//!
//! **Known limits**: relocation rewires in-process channels only (a
//! TCP-fed pellet keeps its receiver endpoint); an adaptation
//! [`crate::adaptation::Monitor`] started at launch keeps observing a
//! relocated pellet's old handle until the monitor is restarted; a
//! delta carries at most one relocation, and may not pause a direct
//! downstream of the relocated pellet — both rejected at plan compile
//! (they would let a handoff fail after the point of no return, or
//! block the backlog replay against a paused queue; split such edits
//! into separate deltas); and a count/time window partially
//! accumulated inside a dispatcher is not part of a relocation
//! handoff (the same exposure `Flake::checkpoint` has always had).

mod delta;
pub(crate) mod engine;
mod plan;

pub use delta::{DeltaOp, GraphDelta};
pub use engine::RecomposeStats;
pub use plan::{compile, RecomposePlan};
