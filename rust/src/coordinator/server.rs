//! Coordinator REST endpoint (§III: "the coordinator, manager, container
//! and flake expose REST web service endpoints").
//!
//! Routes:
//! * `GET  /graph`                       — the graph's XML description
//! * `GET  /stats`                       — per-pellet runtime stats (JSON)
//! * `GET  /metrics`                     — Prometheus text exposition
//! * `GET  /trace?since={seq}`           — control-action trace (JSON)
//! * `GET  /health`                      — liveness summary (JSON)
//! * `POST /inject/{pellet}/{port}`      — inject a text message (body)
//! * `POST /update/{pellet}?class=&mode=sync|async` — dynamic task update
//! * `POST /pause/{pellet}` / `POST /resume/{pellet}`
//! * `POST /cores/{pellet}?n=`           — manual core regrant

use std::sync::Arc;

use super::RunningDataflow;
use crate::error::Result;
use crate::message::Message;
use crate::util::http::{HttpServer, Request, Response};
use crate::util::json::Json;

/// HTTP facade over a running dataflow.
pub struct CoordinatorServer {
    server: HttpServer,
}

impl CoordinatorServer {
    /// Serve `run` on `127.0.0.1:port` (0 = ephemeral).
    pub fn start(
        run: Arc<RunningDataflow>,
        port: u16,
    ) -> Result<CoordinatorServer> {
        let server = HttpServer::start(port, move |req| handle(&run, req))?;
        Ok(CoordinatorServer { server })
    }

    pub fn addr(&self) -> String {
        self.server.addr()
    }

    pub fn shutdown(&mut self) {
        self.server.shutdown();
    }
}

fn handle(run: &RunningDataflow, req: &Request) -> Response {
    let parts: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), parts.as_slice()) {
        ("GET", ["graph"]) => Response {
            status: 200,
            content_type: "application/xml".into(),
            body: run.graph().to_xml().into_bytes(),
        },
        ("GET", ["stats"]) => {
            Response::ok_json(run.stats_json().to_string())
        }
        ("GET", ["metrics"]) => {
            // Every family is present even on an idle dataflow, and
            // queue-depth gauges reflect this scrape.
            crate::telemetry::touch();
            for p in &run.stats().pellets {
                crate::telemetry::gauge_queue_depth(&p.id)
                    .set(p.queue as u64);
            }
            Response {
                status: 200,
                content_type: "text/plain; version=0.0.4".into(),
                body: crate::telemetry::metrics()
                    .render()
                    .into_bytes(),
            }
        }
        ("GET", ["trace"]) => {
            let since = req
                .query_get("since")
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(0);
            let events = crate::telemetry::tracelog().since(since);
            let arr: Vec<Json> =
                events.iter().map(trace_event_json).collect();
            Response::ok_json(Json::Arr(arr).to_string())
        }
        ("GET", ["health"]) => {
            let stats = run.stats();
            let degraded = stats.pellets.iter().any(|p| {
                run.container(&p.id)
                    .map(|c| c.is_dead())
                    .unwrap_or(true)
            });
            let doc = Json::obj(vec![
                (
                    "status",
                    Json::str(if degraded { "degraded" } else { "ok" }),
                ),
                ("pellets", Json::num(stats.pellets.len() as f64)),
                (
                    "failures",
                    Json::num(stats.failures.len() as f64),
                ),
                ("repairs", Json::num(stats.repairs.len() as f64)),
                (
                    "endpoints",
                    Json::num(stats.endpoints.published as f64),
                ),
            ]);
            Response::ok_json(doc.to_string())
        }
        ("POST", ["inject", pellet, port]) => {
            match run.inject(pellet, port, Message::text(req.body_str())) {
                Ok(()) => Response::ok_json("{\"ok\":true}"),
                Err(e) => Response::error(404, e.to_string()),
            }
        }
        ("POST", ["update", pellet]) => {
            let class = req.query_get("class");
            let sync = req.query_get("mode") != Some("async");
            let landmark = req.query_get("landmark") == Some("true");
            match run.update_pellet(pellet, class, sync, landmark) {
                Ok(v) => {
                    Response::ok_json(format!("{{\"version\":{v}}}"))
                }
                Err(e) => Response::error(409, e.to_string()),
            }
        }
        ("POST", ["pause", pellet]) => match run.flake(pellet) {
            Ok(f) => {
                f.pause();
                Response::ok_json("{\"ok\":true}")
            }
            Err(e) => Response::error(404, e.to_string()),
        },
        ("POST", ["resume", pellet]) => match run.flake(pellet) {
            Ok(f) => {
                f.resume();
                Response::ok_json("{\"ok\":true}")
            }
            Err(e) => Response::error(404, e.to_string()),
        },
        ("POST", ["cores", pellet]) => {
            let n = req.query_get("n").and_then(|v| v.parse::<usize>().ok());
            match (run.flake(pellet), n) {
                (Ok(f), Some(n)) => {
                    f.set_cores(n);
                    Response::ok_json("{\"ok\":true}")
                }
                (Err(e), _) => Response::error(404, e.to_string()),
                (_, None) => Response::error(400, "missing ?n="),
            }
        }
        _ => Response::error(404, "unknown coordinator path"),
    }
}

/// One trace event as a JSON object (the `GET /trace` array).
fn trace_event_json(e: &crate::telemetry::TraceEvent) -> Json {
    Json::obj(vec![
        ("seq", Json::num(e.seq as f64)),
        ("t_ms", Json::num(e.t_ms)),
        ("kind", Json::str(e.kind.clone())),
        ("phase", Json::str(e.phase.as_str())),
        ("target", Json::str(e.target.clone())),
        ("outcome", Json::str(e.outcome.clone())),
    ])
}
