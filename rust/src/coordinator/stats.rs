//! Typed stats for a running dataflow.
//!
//! [`DataflowStats`] is the structured form of what
//! [`crate::coordinator::RunningDataflow::stats_json`] has always
//! served: in-process consumers read fields instead of re-parsing the
//! JSON document, and `to_json()` emits the exact same shape (the REST
//! control plane keeps serving it verbatim), extended with `failures`
//! and `repairs` sections from the fault-tolerance subsystem.

use crate::telemetry::HistogramSummary;
use crate::util::json::Json;

use super::{FailureEvent, RepairEvent};

/// One pellet's live observation (one entry of the `pellets` array).
#[derive(Debug, Clone)]
pub struct PelletStats {
    pub id: String,
    pub class: String,
    pub cores: usize,
    pub instances: usize,
    pub queue: usize,
    pub arrival_rate: f64,
    pub latency: f64,
    pub selectivity: f64,
    pub version: u64,
}

impl PelletStats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("class", Json::str(self.class.clone())),
            ("cores", Json::num(self.cores as f64)),
            ("instances", Json::num(self.instances as f64)),
            ("queue", Json::num(self.queue as f64)),
            ("arrival_rate", Json::num(self.arrival_rate)),
            ("latency", Json::num(self.latency)),
            ("selectivity", Json::num(self.selectivity)),
            ("version", Json::num(self.version as f64)),
        ])
    }
}

/// Endpoint-table summary (the `endpoints` object).
#[derive(Debug, Clone, Copy)]
pub struct EndpointInfo {
    /// Table version (bumped by every republication).
    pub version: u64,
    /// Logical addresses currently published.
    pub published: usize,
}

/// Aggregated stats document, typed (see
/// [`crate::coordinator::RunningDataflow::stats`]).
#[derive(Debug, Clone)]
pub struct DataflowStats {
    pub graph: String,
    pub graph_version: u64,
    /// Applied surgeries so far (including automatic repairs).
    pub recomposes: usize,
    pub endpoints: EndpointInfo,
    /// Clock reading the pellet observations were taken at (seconds).
    pub t: f64,
    pub pellets: Vec<PelletStats>,
    /// Container failures detected by the lease detector, oldest
    /// first; empty when fault tolerance is off.
    pub failures: Vec<FailureEvent>,
    /// Flakes re-spawned by `ReplaceFailed` repairs, oldest first.
    pub repairs: Vec<RepairEvent>,
    /// Quantile digests of every telemetry histogram series (empty
    /// until instruments have registered; see [`crate::telemetry`]).
    pub telemetry: Vec<HistogramSummary>,
}

impl DataflowStats {
    /// Serialize to the wire shape `stats_json()` serves.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", Json::str(self.graph.clone())),
            ("graph_version", Json::num(self.graph_version as f64)),
            ("recomposes", Json::num(self.recomposes as f64)),
            (
                "endpoints",
                Json::obj(vec![
                    (
                        "version",
                        Json::num(self.endpoints.version as f64),
                    ),
                    (
                        "published",
                        Json::num(self.endpoints.published as f64),
                    ),
                ]),
            ),
            ("t", Json::num(self.t)),
            (
                "pellets",
                Json::Arr(
                    self.pellets.iter().map(|p| p.to_json()).collect(),
                ),
            ),
            (
                "failures",
                Json::obj(vec![
                    (
                        "detected",
                        Json::num(self.failures.len() as f64),
                    ),
                    (
                        "events",
                        Json::Arr(
                            self.failures
                                .iter()
                                .map(|e| e.to_json())
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "repairs",
                Json::obj(vec![
                    (
                        "completed",
                        Json::num(self.repairs.len() as f64),
                    ),
                    (
                        "events",
                        Json::Arr(
                            self.repairs
                                .iter()
                                .map(|e| e.to_json())
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "telemetry",
                Json::Arr(
                    self.telemetry
                        .iter()
                        .map(summary_to_json)
                        .collect(),
                ),
            ),
        ])
    }
}

/// One histogram digest as a JSON object (the `telemetry` array).
fn summary_to_json(s: &HistogramSummary) -> Json {
    let mut fields = vec![("name", Json::str(s.name.clone()))];
    if let Some((k, v)) = &s.label {
        fields.push(("label_key", Json::str(k.clone())));
        fields.push(("label_value", Json::str(v.clone())));
    }
    fields.push(("count", Json::num(s.count as f64)));
    fields.push(("sum", Json::num(s.sum as f64)));
    fields.push(("p50", Json::num(s.p50 as f64)));
    fields.push(("p90", Json::num(s.p90 as f64)));
    fields.push(("p99", Json::num(s.p99 as f64)));
    fields.push(("max", Json::num(s.max as f64)));
    Json::obj(fields)
}
