//! The coordinator (§III): parses the Floe graph, negotiates cores with the
//! resource manager, places flakes in containers (best fit), wires the
//! dataflow **bottom-up** so upstream pellets never emit into unwired
//! sinks, activates the graph, and orchestrates application dynamism —
//! in-place task updates, coordinated sub-graph updates, the cascading
//! "wave" update, and full structural surgery on the live topology via
//! [`crate::recompose`].

mod server;

pub use server::CoordinatorServer;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::adaptation::{FlakeDirectory, Monitor, StrategyFactory};
use crate::channel::{
    ChannelBackend, EndpointAddr, EndpointTable, EndpointTransport,
    Transport,
};
use crate::error::{FloeError, Result};
use crate::flake::{Flake, FlakeConfig};
use crate::graph::DataflowGraph;
use crate::manager::ResourceManager;
use crate::message::Message;
use crate::pellet::PelletRegistry;
use crate::recompose::{GraphDelta, RecomposeStats};
use crate::util::json::Json;
use crate::util::time::{Clock, WallClock};

/// Launch options.
pub struct LaunchOptions {
    /// Instances per core.
    pub alpha: usize,
    /// Input queue capacity per port (aggregate across the port's
    /// shards: each shard holds `queue_capacity / input_shards`, so a
    /// single producer thread blocks at that per-shard bound).
    pub queue_capacity: usize,
    /// Messages moved per batched channel operation on the hot path
    /// (see [`crate::flake::FlakeConfig::batch_size`]); 1 disables
    /// batching.
    pub batch_size: usize,
    /// Producer shards per flake input port.
    pub input_shards: usize,
    /// Which primitive backs each input-port shard (lock-free ring by
    /// default; [`ChannelBackend::Mutex`] selects the reference queue).
    pub channel_backend: ChannelBackend,
    /// Adaptation strategy factory per pellet id; None = no monitor.
    pub adaptation: Option<AdaptationSetup>,
}

/// Monitor configuration for a launch.
pub struct AdaptationSetup {
    /// Build a strategy for a pellet id.  Also used to auto-watch
    /// pellets added by later graph surgery (see
    /// [`Monitor::start_auto`]).
    pub make: StrategyFactory,
    /// Sampling interval.
    pub interval: Duration,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            alpha: crate::ALPHA,
            queue_capacity: 4096,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: crate::channel::DEFAULT_SHARDS,
            channel_backend: ChannelBackend::default(),
            adaptation: None,
        }
    }
}

/// The per-flake knobs a launch fixes; retained so pellets added by
/// later graph surgery match the launch configuration.
#[derive(Debug, Clone, Copy)]
pub struct FlakeTuning {
    pub alpha: usize,
    pub queue_capacity: usize,
    pub batch_size: usize,
    pub input_shards: usize,
    pub channel_backend: ChannelBackend,
}

impl FlakeTuning {
    fn from_options(options: &LaunchOptions) -> FlakeTuning {
        FlakeTuning {
            alpha: options.alpha,
            queue_capacity: options.queue_capacity,
            batch_size: options.batch_size.max(1),
            input_shards: options.input_shards.max(1),
            channel_backend: options.channel_backend,
        }
    }

    pub(crate) fn apply(&self, cfg: &mut FlakeConfig) {
        cfg.alpha = self.alpha;
        cfg.queue_capacity = self.queue_capacity;
        cfg.batch_size = self.batch_size;
        cfg.input_shards = self.input_shards;
        cfg.channel_backend = self.channel_backend;
    }
}

/// The mutable topology of a running dataflow: the versioned graph and
/// the live flake/container placement.  Guarded by one `RwLock` so the
/// recomposition engine can swap all three consistently while readers
/// (ingress, stats, drains) see either the old or the new topology,
/// never a mix.
///
/// The authoritative [`EndpointTable`] rides inside the topology: it
/// is the logical → physical half of the placement, republished by
/// the engine whenever a flake moves, and senders resolve through it
/// rather than holding queue/socket handles (see
/// `crate::channel::endpoint`).  It is internally versioned and
/// lock-free to read, so it is shared as an `Arc` rather than guarded
/// by the topology lock.
pub(crate) struct Topology {
    pub(crate) graph: DataflowGraph,
    pub(crate) flakes: HashMap<String, Arc<Flake>>,
    pub(crate) containers:
        HashMap<String, Arc<crate::container::Container>>,
    pub(crate) endpoints: Arc<EndpointTable>,
}

/// The adaptation [`Monitor`] resolves pellet ids against the live
/// topology through this impl, so relocated flakes are re-bound to
/// their replacement and removed flakes are dropped (never sampled as
/// dead handles).
impl FlakeDirectory for RwLock<Topology> {
    fn lookup(
        &self,
        pellet_id: &str,
    ) -> Option<(Arc<Flake>, Arc<crate::container::Container>)> {
        let topo = self.read().expect("topology poisoned");
        Some((
            Arc::clone(topo.flakes.get(pellet_id)?),
            Arc::clone(topo.containers.get(pellet_id)?),
        ))
    }

    fn pellet_ids(&self) -> Vec<String> {
        self.read()
            .expect("topology poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }
}

/// A launched continuous dataflow.
pub struct RunningDataflow {
    pub(crate) topo: Arc<RwLock<Topology>>,
    pub(crate) registry: PelletRegistry,
    pub(crate) manager: Arc<ResourceManager>,
    pub(crate) tuning: FlakeTuning,
    monitor: Mutex<Option<Monitor>>,
    clock: Arc<dyn Clock>,
    /// Serializes structural surgeries *and* the in-place update
    /// entry points: a sync `update_pellet` pauses/resumes flakes, so
    /// letting it interleave with a recompose would resume a flake
    /// the engine had quiesced mid-cut-over.
    recompose_gate: Mutex<()>,
    recompose_log: Mutex<Vec<RecomposeStats>>,
}

impl RunningDataflow {
    /// The container hosting a pellet's flake (for manual core regrants).
    pub fn container(
        &self,
        pellet_id: &str,
    ) -> Result<Arc<crate::container::Container>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .containers
            .get(pellet_id)
            .cloned()
            .ok_or_else(|| {
                FloeError::Graph(format!(
                    "no container for pellet '{pellet_id}'"
                ))
            })
    }

    /// The flake executing a pellet.
    pub fn flake(&self, pellet_id: &str) -> Result<Arc<Flake>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .flakes
            .get(pellet_id)
            .cloned()
            .ok_or_else(|| {
                FloeError::Graph(format!("no flake for pellet '{pellet_id}'"))
            })
    }

    pub fn pellet_ids(&self) -> Vec<String> {
        self.topo
            .read()
            .expect("topology poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }

    /// A snapshot of the current (versioned) graph.
    pub fn graph(&self) -> DataflowGraph {
        self.topo.read().expect("topology poisoned").graph.clone()
    }

    /// Current topology version (bumped by every applied delta).
    pub fn graph_version(&self) -> u64 {
        self.topo.read().expect("topology poisoned").graph.version
    }

    /// The dataflow's authoritative logical → physical endpoint table.
    /// Remote senders hold this (plus a `floe://<flake>/<port>`
    /// address) instead of a socket address, so they follow flake
    /// relocations automatically.
    pub fn endpoints(&self) -> Arc<EndpointTable> {
        Arc::clone(&self.topo.read().expect("topology poisoned").endpoints)
    }

    /// Bind a TCP ingress endpoint (`127.0.0.1:port`, 0 = ephemeral)
    /// for a pellet's input ports and record it under the pellet's
    /// logical address.  Returns the bound `host:port`.  The fed flake
    /// stays fully relocatable: connect with
    /// `TcpSender::logical(run.endpoints(), &EndpointAddr::new(id,
    /// port))` and the sender rebinds across moves.
    pub fn serve_tcp(&self, pellet_id: &str, port: u16) -> Result<String> {
        self.flake(pellet_id)?.serve_tcp(port)
    }

    /// Snapshot of live flake handles (lock dropped before return).
    fn flake_snapshot(&self) -> Vec<Arc<Flake>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .flakes
            .values()
            .cloned()
            .collect()
    }

    /// Inject a message into a source pellet's input port (the paper's
    /// "initial inputs" entry point returned by the coordinator).
    ///
    /// The flake is resolved under the topology read lock, but the
    /// (possibly blocking) queue push happens after the lock is
    /// dropped, so backpressure on a paused pellet can never deadlock
    /// an in-flight surgery.  If the resolved flake was torn down
    /// mid-push (relocation closes the old queues behind its capture),
    /// the inject re-resolves and retries, which preserves
    /// per-producer FIFO: the retried message lands after the captured
    /// backlog was replayed into the replacement.
    pub fn inject(
        &self,
        pellet_id: &str,
        port: &str,
        msg: Message,
    ) -> Result<()> {
        // The retry copy is an Arc bump (payloads are shared), not a
        // payload clone; the final attempt moves the message.
        const ATTEMPTS: usize = 8;
        for _ in 0..ATTEMPTS - 1 {
            let flake = self.flake(pellet_id)?;
            match flake.inject(port, msg.clone()) {
                Ok(()) => return Ok(()),
                // Only a closed input queue is transient (the flake is
                // being replaced); anything else — unknown port, bad
                // pellet — is permanent and surfaces immediately.
                Err(FloeError::Channel(_)) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e),
            }
        }
        self.flake(pellet_id)?.inject(port, msg)
    }

    /// Wait for all flakes to drain (tests, graceful stop).  The idle
    /// condition must hold across consecutive checks because a message
    /// can transiently be in neither a queue nor an in-flight counter
    /// while a thread moves it between flakes.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut idle_streak = 0;
        loop {
            let busy = self.flake_snapshot().iter().any(|f| {
                f.queue_len() > 0
                    || f.ready_len() > 0
                    || f.probes()
                        .inflight
                        .load(std::sync::atomic::Ordering::SeqCst)
                        > 0
            });
            if !busy {
                idle_streak += 1;
                if idle_streak >= 3 {
                    return true;
                }
            } else {
                idle_streak = 0;
            }
            if std::time::Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// **Dynamic task update** by pellet id: re-resolve the pellet's class
    /// (or a new class) in the registry and swap in place (§II-B).
    pub fn update_pellet(
        &self,
        pellet_id: &str,
        new_class: Option<&str>,
        sync: bool,
        landmark: bool,
    ) -> Result<u64> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        let flake = self.flake(pellet_id)?;
        let class = new_class.unwrap_or_else(|| flake.class());
        let factory = self.registry.resolve(class)?;
        flake.update_pellet(factory, sync, landmark)
    }

    /// **Dynamic dataflow (sub-graph) update**: update several pellets in a
    /// coordinated manner — all intake paused, all swapped, all resumed —
    /// so downstream pellets see a consistent cut-over (§II-B).
    pub fn update_subgraph(
        &self,
        updates: &[(String, String)],
        landmark: bool,
    ) -> Result<()> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        // Validate everything first so we never pause on a bad request.
        let mut planned = Vec::new();
        for (pellet_id, class) in updates {
            let flake = self.flake(pellet_id)?;
            let factory = self.registry.resolve(class)?;
            planned.push((flake, factory));
        }
        for (flake, _) in &planned {
            flake.pause();
        }
        let result: Result<()> = (|| {
            for (flake, factory) in &planned {
                // Synchronous per-flake swap; intake already paused for the
                // whole sub-graph, so the slowest drain gates the cut-over.
                flake.update_pellet(Arc::clone(factory), true, landmark)?;
            }
            Ok(())
        })();
        for (flake, _) in &planned {
            flake.resume();
        }
        result
    }

    /// **Cascading "wave" update** (§II-B future work, implemented):
    /// updates pellets one by one in upstream→downstream order, emitting an
    /// Update landmark at each hop, so a clear wavefront separates
    /// pre-update from post-update streams without a global pause.
    ///
    /// Every pellet id is validated and every class resolved *before*
    /// the first swap: a bad entry anywhere in the update set fails the
    /// whole wave up front instead of leaving upstream flakes updated
    /// and the rest untouched.
    pub fn wave_update(
        &self,
        updates: &[(String, String)],
    ) -> Result<Vec<u64>> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        let order = self.graph().wiring_order()?; // downstream-first
        let mut planned = Vec::new();
        // Reverse = upstream-first traversal of the sub-graph.
        for id in order.iter().rev() {
            if let Some((_, class)) =
                updates.iter().find(|(p, _)| p == id)
            {
                let flake = self.flake(id)?;
                let factory = self.registry.resolve(class)?;
                planned.push((flake, factory));
            }
        }
        if planned.len() != updates.len() {
            return Err(FloeError::Graph(
                "wave_update: some pellets not in graph".into(),
            ));
        }
        let mut versions = Vec::new();
        for (flake, factory) in planned {
            versions.push(flake.update_pellet(factory, true, true)?);
        }
        Ok(versions)
    }

    /// **Live graph surgery** (§II-B "dynamic recomposition"): apply a
    /// [`GraphDelta`] — add/remove pellets and edges, splice a pellet
    /// into a live edge, retarget edges, relocate flakes across
    /// containers — while the stream keeps flowing.  See
    /// [`crate::recompose`] for semantics and guarantees.  Surgeries
    /// are serialized per dataflow; the returned [`RecomposeStats`]
    /// reports the measured pause-to-resume downtime.
    pub fn recompose(&self, delta: &GraphDelta) -> Result<RecomposeStats> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        let engine = crate::recompose::engine::RecomposeEngine::new(self);
        let stats = engine.execute(delta)?;
        self.recompose_log
            .lock()
            .expect("recompose log poisoned")
            .push(stats.clone());
        Ok(stats)
    }

    /// Release every container no flake lives in back to the cloud
    /// (scale-in).  Serialized with surgeries via the recompose gate:
    /// a concurrent relocation's freshly allocated — still empty —
    /// container can never be swept out from under the engine between
    /// placement and spawn.  Returns how many containers were
    /// released.
    pub fn release_idle_containers(&self) -> Result<usize> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        self.manager.release_idle()
    }

    /// Every applied surgery with its measured downtime, oldest first.
    pub fn recompose_history(&self) -> Vec<RecomposeStats> {
        self.recompose_log
            .lock()
            .expect("recompose log poisoned")
            .clone()
    }

    /// Snapshot of the adaptation monitor's decision history (the live
    /// Fig. 4 series); empty when no adaptation was configured.
    pub fn adaptation_history(
        &self,
    ) -> Vec<crate::adaptation::AdaptationSample> {
        self.monitor
            .lock()
            .expect("monitor poisoned")
            .as_ref()
            .map(|m| m.history().snapshot())
            .unwrap_or_default()
    }

    /// Aggregated stats document (served by the coordinator endpoint).
    pub fn stats_json(&self) -> Json {
        let t = self.clock.now();
        let mut pellets = Vec::new();
        let (graph_name, graph_version, flakes, endpoints) = {
            let topo = self.topo.read().expect("topology poisoned");
            let flakes: Vec<(String, Arc<Flake>)> = topo
                .flakes
                .iter()
                .map(|(id, f)| (id.clone(), Arc::clone(f)))
                .collect();
            (
                topo.graph.name.clone(),
                topo.graph.version,
                flakes,
                Arc::clone(&topo.endpoints),
            )
        };
        for (id, f) in &flakes {
            let obs = f.observe(t);
            pellets.push(Json::obj(vec![
                ("id", Json::str(id.clone())),
                ("class", Json::str(f.class())),
                ("cores", Json::num(obs.cores as f64)),
                ("instances", Json::num(obs.instances as f64)),
                ("queue", Json::num(obs.queue_len as f64)),
                ("arrival_rate", Json::num(obs.arrival_rate)),
                ("latency", Json::num(obs.service_latency)),
                ("selectivity", Json::num(obs.selectivity)),
                ("version", Json::num(f.version() as f64)),
            ]));
        }
        Json::obj(vec![
            ("graph", Json::str(graph_name)),
            ("graph_version", Json::num(graph_version as f64)),
            (
                "recomposes",
                Json::num(
                    self.recompose_log
                        .lock()
                        .expect("recompose log poisoned")
                        .len() as f64,
                ),
            ),
            (
                "endpoints",
                Json::obj(vec![
                    (
                        "version",
                        Json::num(endpoints.version() as f64),
                    ),
                    (
                        "published",
                        Json::num(endpoints.published() as f64),
                    ),
                ]),
            ),
            ("t", Json::num(t)),
            ("pellets", Json::Arr(pellets)),
        ])
    }

    /// Stop the monitor and all flakes.
    pub fn stop(&self) {
        if let Some(mut m) =
            self.monitor.lock().expect("monitor poisoned").take()
        {
            m.stop();
        }
        let (order, flakes) = {
            let topo = self.topo.read().expect("topology poisoned");
            (topo.graph.wiring_order(), topo.flakes.clone())
        };
        // Stop sources first (wiring order reversed = sources first), so
        // downstream flakes drain naturally before shutdown.
        if let Ok(order) = order {
            for id in order.iter().rev() {
                if let Some(f) = flakes.get(id) {
                    f.shutdown();
                }
            }
        }
        for f in flakes.values() {
            f.shutdown();
        }
    }
}

impl Drop for RunningDataflow {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The coordinator.
pub struct Coordinator {
    manager: Arc<ResourceManager>,
    registry: PelletRegistry,
}

impl Coordinator {
    pub fn new(
        manager: Arc<ResourceManager>,
        registry: PelletRegistry,
    ) -> Coordinator {
        Coordinator { manager, registry }
    }

    /// Parse, place, wire (bottom-up BFS ignoring loops) and activate a
    /// graph.  Returns the running dataflow handle with ingress access.
    pub fn launch(
        &self,
        graph: DataflowGraph,
        options: LaunchOptions,
    ) -> Result<RunningDataflow> {
        graph.validate()?;
        let order = graph.wiring_order()?;
        crate::log_info!(
            "coordinator: launching '{}' ({} pellets), wiring order {:?}",
            graph.name,
            graph.pellets.len(),
            order
        );
        let tuning = FlakeTuning::from_options(&options);

        // 1. Instantiate flakes bottom-up so every sink exists before any
        //    upstream pellet could emit, publishing each flake's input
        //    ports into the dataflow's endpoint table as it spawns.
        let endpoints = EndpointTable::new();
        let mut flakes: HashMap<String, Arc<Flake>> = HashMap::new();
        let mut containers = HashMap::new();
        for id in &order {
            let spec = graph
                .pellet(id)
                .ok_or_else(|| {
                    FloeError::Graph(format!("missing pellet '{id}'"))
                })?
                .clone();
            let factory = self.registry.resolve(&spec.class)?;
            let mut cfg = FlakeConfig::from_spec(&spec);
            tuning.apply(&mut cfg);
            let container = self.manager.allocate(cfg.cores)?;
            let flake = container.spawn_flake(cfg, factory)?;
            flake.publish_endpoints(&endpoints);
            containers.insert(id.clone(), Arc::clone(&container));
            flakes.insert(id.clone(), flake);
        }

        // 2. Wire edges, still bottom-up by source pellet.  Edges are
        //    *logical*: each transport holds the sink's
        //    `floe://<flake>/<port>` address and resolves it through
        //    the versioned endpoint table per send, so a later
        //    relocation republishes the sink and every edge follows
        //    without rewiring.  The sink's port is still validated
        //    eagerly — a bad edge fails the launch, not the stream.
        for id in &order {
            let spec = graph.pellet(id).expect("validated");
            for out in &spec.outputs {
                for edge in graph.edges_from(id, &out.name) {
                    let sink = &flakes[&edge.to_pellet];
                    sink.input_queue(&edge.to_port)?; // validate
                    let transport: Arc<dyn Transport> =
                        Arc::new(EndpointTransport::new(
                            Arc::clone(&endpoints),
                            EndpointAddr::new(
                                edge.to_pellet.clone(),
                                edge.to_port.clone(),
                            ),
                            format!(
                                "{}.{} -> {}.{}",
                                edge.from_pellet,
                                edge.from_port,
                                edge.to_pellet,
                                edge.to_port
                            ),
                        ));
                    flakes[id].wire_output(&out.name, transport)?;
                }
            }
        }

        let clock: Arc<dyn Clock> = Arc::new(WallClock::new());
        let topo = Arc::new(RwLock::new(Topology {
            graph,
            flakes,
            containers,
            endpoints,
        }));

        // 3. Optional adaptation monitor.  Entries are pellet *ids*
        //    discovered from the shared topology on every tick, so
        //    later graph surgery re-binds relocated flakes, drops
        //    removed ones, and auto-watches newly added pellets (see
        //    `FlakeDirectory` / `Monitor::start_auto`).
        let monitor = options.adaptation.map(|setup| {
            Monitor::start_auto(
                setup.make,
                Arc::clone(&topo) as Arc<dyn FlakeDirectory>,
                Arc::clone(&clock),
                setup.interval,
            )
        });

        Ok(RunningDataflow {
            topo,
            registry: self.registry.clone(),
            manager: Arc::clone(&self.manager),
            tuning,
            monitor: Mutex::new(monitor),
            clock,
            recompose_gate: Mutex::new(()),
            recompose_log: Mutex::new(Vec::new()),
        })
    }

    pub fn registry(&self) -> &PelletRegistry {
        &self.registry
    }

    pub fn manager(&self) -> &Arc<ResourceManager> {
        &self.manager
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, SplitMode};
    use crate::manager::SimulatedCloud;
    use crate::pellet::builtins::CollectSink;
    use std::sync::Mutex as StdMutex;

    fn coordinator() -> Coordinator {
        let cloud = SimulatedCloud::new(256, Duration::ZERO);
        let mgr = ResourceManager::new(cloud);
        Coordinator::new(mgr, PelletRegistry::with_builtins())
    }

    fn collect_class(
        reg: &PelletRegistry,
        class: &str,
    ) -> Arc<StdMutex<Vec<Message>>> {
        let sink = Arc::new(StdMutex::new(Vec::new()));
        let s2 = Arc::clone(&sink);
        reg.register(class, move || {
            Box::new(CollectSink { collected: Arc::clone(&s2) })
        });
        sink
    }

    #[test]
    fn launch_linear_pipeline_end_to_end() {
        let coord = coordinator();
        let sink = collect_class(coord.registry(), "test.Collect");

        let mut g = GraphBuilder::new("lin");
        g.pellet("up", "floe.builtin.Uppercase")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        g.pellet("sink", "test.Collect").in_port("in");
        g.edge("up", "out", "sink", "in");
        let run = coord
            .launch(g.build().unwrap(), LaunchOptions::default())
            .unwrap();

        for i in 0..20 {
            run.inject("up", "in", Message::text(format!("m{i}"))).unwrap();
        }
        assert!(run.drain(Duration::from_secs(5)));
        let got = sink.lock().unwrap();
        assert_eq!(got.len(), 20);
        assert!(got.iter().all(|m| m.as_text().unwrap().starts_with('M')));
        drop(got);
        run.stop();
    }

    #[test]
    fn launch_rejects_unknown_class() {
        let coord = coordinator();
        let mut g = GraphBuilder::new("bad");
        g.pellet("x", "no.such.Class");
        let err =
            coord.launch(g.build().unwrap(), LaunchOptions::default());
        assert!(err.is_err());
    }

    #[test]
    fn stats_json_lists_pellets() {
        let coord = coordinator();
        let mut g = GraphBuilder::new("s");
        g.pellet("id1", "floe.builtin.Identity")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        let run = coord
            .launch(g.build().unwrap(), LaunchOptions::default())
            .unwrap();
        let stats = run.stats_json();
        assert_eq!(
            stats.get("graph").unwrap().as_str().unwrap(),
            "s"
        );
        assert_eq!(
            stats.get("graph_version").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            stats.get("pellets").unwrap().as_arr().unwrap().len(),
            1
        );
        run.stop();
    }
}
