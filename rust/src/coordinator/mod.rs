//! The coordinator (§III): parses the Floe graph, negotiates cores with the
//! resource manager, places flakes in containers (best fit), wires the
//! dataflow **bottom-up** so upstream pellets never emit into unwired
//! sinks, activates the graph, and orchestrates application dynamism —
//! in-place task updates, coordinated sub-graph updates, the cascading
//! "wave" update, full structural surgery on the live topology via
//! [`crate::recompose`], and automatic failure repair via
//! [`failure::FailureDetector`].

mod failure;
mod server;
mod stats;

pub use failure::{
    report_endpoint_stall, FailureEvent, FaultToleranceConfig,
    LeaseTracker, RepairEvent, StallReport,
};
pub(crate) use failure::FailureDetector;
pub use server::CoordinatorServer;
pub use stats::{DataflowStats, EndpointInfo, PelletStats};

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::adaptation::{
    ElasticityConfig, FlakeDirectory, Monitor, StrategyFactory,
};
use crate::channel::{
    ChannelBackend, EndpointAddr, EndpointTable, EndpointTransport,
    Transport,
};
use crate::error::{FloeError, Result};
use crate::flake::{Flake, FlakeCheckpoint, FlakeConfig};
use crate::graph::DataflowGraph;
use crate::manager::ResourceManager;
use crate::message::Message;
use crate::pellet::PelletRegistry;
use crate::recompose::{GraphDelta, RecomposeStats};
use crate::util::json::Json;
use crate::util::time::{Clock, WallClock};

/// Unified, builder-style runtime options: every knob a launch fixes —
/// flake tuning, channel backend, adaptation, elasticity and fault
/// tolerance — in one place.
///
/// ```no_run
/// use floe::prelude::*;
/// use std::time::Duration;
///
/// let options = RuntimeOptions::new()
///     .batch_size(64)
///     .backend(ChannelBackend::Ring)
///     .checkpoint_interval(Duration::from_millis(250));
/// ```
///
/// Consumed by [`Coordinator::launch`] (via `impl Into<RuntimeOptions>`,
/// so the deprecated [`LaunchOptions`] still works for one release) and
/// by [`crate::adaptation::ElasticityPolicy::from_options`].
pub struct RuntimeOptions {
    /// Instances per core.
    pub alpha: usize,
    /// Input queue capacity per port (aggregate across the port's
    /// shards: each shard holds `queue_capacity / input_shards`, so a
    /// single producer thread blocks at that per-shard bound).
    pub queue_capacity: usize,
    /// Messages moved per batched channel operation on the hot path
    /// (see [`crate::flake::FlakeConfig::batch_size`]); 1 disables
    /// batching.
    pub batch_size: usize,
    /// Producer shards per flake input port.
    pub input_shards: usize,
    /// Which primitive backs each input-port shard (lock-free ring by
    /// default; [`ChannelBackend::Mutex`] selects the reference queue).
    pub channel_backend: ChannelBackend,
    /// Drop already-seen [`Message::seq`] values at each input port
    /// (per-port high watermark, captured/restored with checkpoints)
    /// so at-least-once redelivery after a repair does not
    /// double-count.  Requires monotone single-producer delivery per
    /// port; off by default.
    pub dedup: bool,
    /// Adaptation strategy factory per pellet id; None = no monitor.
    pub adaptation: Option<AdaptationSetup>,
    /// Lease-based failure detection + automatic repair; None = a dead
    /// container strands its flakes (the pre-fault-tolerance
    /// behaviour).
    pub fault_tolerance: Option<FaultToleranceConfig>,
    /// Knobs for [`crate::adaptation::ElasticityPolicy`] instances
    /// built from these options.
    pub elasticity: ElasticityConfig,
    /// Hot-path telemetry + 1-in-N end-to-end latency sampling (see
    /// [`crate::telemetry`]); `None` (default) keeps hot-path
    /// instruments off — control-plane events still record.
    pub telemetry: Option<crate::telemetry::TelemetryConfig>,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        RuntimeOptions {
            alpha: crate::ALPHA,
            queue_capacity: 4096,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: crate::channel::DEFAULT_SHARDS,
            channel_backend: ChannelBackend::default(),
            dedup: false,
            adaptation: None,
            fault_tolerance: None,
            elasticity: ElasticityConfig::default(),
            telemetry: None,
        }
    }
}

impl RuntimeOptions {
    pub fn new() -> RuntimeOptions {
        RuntimeOptions::default()
    }

    /// Instances per core.
    pub fn alpha(mut self, alpha: usize) -> Self {
        self.alpha = alpha;
        self
    }

    /// Aggregate input queue capacity per port.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity;
        self
    }

    /// Messages per batched channel operation (1 disables batching).
    pub fn batch_size(mut self, batch: usize) -> Self {
        self.batch_size = batch;
        self
    }

    /// Producer shards per flake input port.
    pub fn input_shards(mut self, shards: usize) -> Self {
        self.input_shards = shards;
        self
    }

    /// Channel primitive backing each input-port shard.
    pub fn backend(mut self, backend: ChannelBackend) -> Self {
        self.channel_backend = backend;
        self
    }

    /// Toggle sequence-number dedup at every input port.
    pub fn dedup(mut self, on: bool) -> Self {
        self.dedup = on;
        self
    }

    /// Watch every pellet with a strategy built by `make`, sampling at
    /// `interval`.
    pub fn adaptation(
        mut self,
        make: StrategyFactory,
        interval: Duration,
    ) -> Self {
        self.adaptation = Some(AdaptationSetup { make, interval });
        self
    }

    /// Enable failure detection + automatic repair with full control
    /// over the lease knobs.
    pub fn fault_tolerance(mut self, cfg: FaultToleranceConfig) -> Self {
        self.fault_tolerance = Some(cfg);
        self
    }

    /// Enable periodic checkpoints every `interval` (turning fault
    /// tolerance on with default lease knobs if it was off).
    pub fn checkpoint_interval(mut self, interval: Duration) -> Self {
        self.fault_tolerance
            .get_or_insert_with(FaultToleranceConfig::default)
            .checkpoint_interval = Some(interval);
        self
    }

    /// Knobs for elasticity policies built from these options.
    pub fn elasticity(mut self, cfg: ElasticityConfig) -> Self {
        self.elasticity = cfg;
        self
    }

    /// Enable hot-path telemetry and 1-in-N end-to-end latency
    /// sampling (see [`crate::telemetry`]).
    pub fn telemetry(
        mut self,
        cfg: crate::telemetry::TelemetryConfig,
    ) -> Self {
        self.telemetry = Some(cfg);
        self
    }
}

/// Launch options (pre-PR 6 shape).
#[deprecated(
    note = "use the builder-style `RuntimeOptions` instead; this shim \
            will be removed next release"
)]
pub struct LaunchOptions {
    /// Instances per core.
    pub alpha: usize,
    /// Input queue capacity per port.
    pub queue_capacity: usize,
    /// Messages moved per batched channel operation on the hot path.
    pub batch_size: usize,
    /// Producer shards per flake input port.
    pub input_shards: usize,
    /// Which primitive backs each input-port shard.
    pub channel_backend: ChannelBackend,
    /// Adaptation strategy factory per pellet id; None = no monitor.
    pub adaptation: Option<AdaptationSetup>,
}

#[allow(deprecated)]
impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            alpha: crate::ALPHA,
            queue_capacity: 4096,
            batch_size: crate::flake::DEFAULT_BATCH_SIZE,
            input_shards: crate::channel::DEFAULT_SHARDS,
            channel_backend: ChannelBackend::default(),
            adaptation: None,
        }
    }
}

#[allow(deprecated)]
impl From<LaunchOptions> for RuntimeOptions {
    fn from(old: LaunchOptions) -> RuntimeOptions {
        RuntimeOptions {
            alpha: old.alpha,
            queue_capacity: old.queue_capacity,
            batch_size: old.batch_size,
            input_shards: old.input_shards,
            channel_backend: old.channel_backend,
            adaptation: old.adaptation,
            ..RuntimeOptions::default()
        }
    }
}

/// Monitor configuration for a launch.
pub struct AdaptationSetup {
    /// Build a strategy for a pellet id.  Also used to auto-watch
    /// pellets added by later graph surgery (see
    /// [`Monitor::start_auto`]).
    pub make: StrategyFactory,
    /// Sampling interval.
    pub interval: Duration,
}

/// The per-flake knobs a launch fixes; retained so pellets added by
/// later graph surgery match the launch configuration.
#[derive(Debug, Clone, Copy)]
pub struct FlakeTuning {
    pub alpha: usize,
    pub queue_capacity: usize,
    pub batch_size: usize,
    pub input_shards: usize,
    pub channel_backend: ChannelBackend,
    pub dedup: bool,
}

impl FlakeTuning {
    fn from_options(options: &RuntimeOptions) -> FlakeTuning {
        FlakeTuning {
            alpha: options.alpha,
            queue_capacity: options.queue_capacity,
            batch_size: options.batch_size.max(1),
            input_shards: options.input_shards.max(1),
            channel_backend: options.channel_backend,
            dedup: options.dedup,
        }
    }

    pub(crate) fn apply(&self, cfg: &mut FlakeConfig) {
        cfg.alpha = self.alpha;
        cfg.queue_capacity = self.queue_capacity;
        cfg.batch_size = self.batch_size;
        cfg.input_shards = self.input_shards;
        cfg.channel_backend = self.channel_backend;
        cfg.dedup = self.dedup;
    }
}

/// The mutable topology of a running dataflow: the versioned graph and
/// the live flake/container placement.  Guarded by one `RwLock` so the
/// recomposition engine can swap all three consistently while readers
/// (ingress, stats, drains) see either the old or the new topology,
/// never a mix.
///
/// The authoritative [`EndpointTable`] rides inside the topology: it
/// is the logical → physical half of the placement, republished by
/// the engine whenever a flake moves, and senders resolve through it
/// rather than holding queue/socket handles (see
/// `crate::channel::endpoint`).  It is internally versioned and
/// lock-free to read, so it is shared as an `Arc` rather than guarded
/// by the topology lock.
pub(crate) struct Topology {
    pub(crate) graph: DataflowGraph,
    pub(crate) flakes: HashMap<String, Arc<Flake>>,
    pub(crate) containers:
        HashMap<String, Arc<crate::container::Container>>,
    pub(crate) endpoints: Arc<EndpointTable>,
}

/// The adaptation [`Monitor`] resolves pellet ids against the live
/// topology through this impl, so relocated flakes are re-bound to
/// their replacement and removed flakes are dropped (never sampled as
/// dead handles).
impl FlakeDirectory for RwLock<Topology> {
    fn lookup(
        &self,
        pellet_id: &str,
    ) -> Option<(Arc<Flake>, Arc<crate::container::Container>)> {
        let topo = self.read().expect("topology poisoned");
        Some((
            Arc::clone(topo.flakes.get(pellet_id)?),
            Arc::clone(topo.containers.get(pellet_id)?),
        ))
    }

    fn pellet_ids(&self) -> Vec<String> {
        self.read()
            .expect("topology poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }
}

/// Everything the background control loops (monitor, failure detector)
/// and the recompose engine share with the user-facing handle.  The
/// [`RunningDataflow`] owns the loops; this inner state is behind an
/// `Arc` so a detector thread can execute a repair recomposition while
/// the handle is busy elsewhere.
pub(crate) struct DataflowInner {
    pub(crate) topo: Arc<RwLock<Topology>>,
    pub(crate) registry: PelletRegistry,
    pub(crate) manager: Arc<ResourceManager>,
    pub(crate) tuning: FlakeTuning,
    pub(crate) clock: Arc<dyn Clock>,
    /// Serializes structural surgeries *and* the in-place update
    /// entry points: a sync `update_pellet` pauses/resumes flakes, so
    /// letting it interleave with a recompose would resume a flake
    /// the engine had quiesced mid-cut-over.  Periodic checkpoints
    /// take it too (a checkpoint pauses/resumes its flake).
    recompose_gate: Mutex<()>,
    recompose_log: Mutex<Vec<RecomposeStats>>,
    /// Last checkpoint per pellet id — what a `ReplaceFailed` repair
    /// restores from.  Entries for removed pellets are dropped by the
    /// engine.
    pub(crate) checkpoints: Mutex<HashMap<String, FlakeCheckpoint>>,
    failures: Mutex<Vec<FailureEvent>>,
    repairs: Mutex<Vec<RepairEvent>>,
}

impl DataflowInner {
    pub(crate) fn flake(&self, pellet_id: &str) -> Result<Arc<Flake>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .flakes
            .get(pellet_id)
            .cloned()
            .ok_or_else(|| {
                FloeError::Graph(format!("no flake for pellet '{pellet_id}'"))
            })
    }

    pub(crate) fn container(
        &self,
        pellet_id: &str,
    ) -> Result<Arc<crate::container::Container>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .containers
            .get(pellet_id)
            .cloned()
            .ok_or_else(|| {
                FloeError::Graph(format!(
                    "no container for pellet '{pellet_id}'"
                ))
            })
    }

    pub(crate) fn graph(&self) -> DataflowGraph {
        self.topo.read().expect("topology poisoned").graph.clone()
    }

    /// Snapshot of live flake handles (lock dropped before return).
    fn flake_snapshot(&self) -> Vec<Arc<Flake>> {
        self.topo
            .read()
            .expect("topology poisoned")
            .flakes
            .values()
            .cloned()
            .collect()
    }

    /// Gated surgery entry point shared by the user-facing
    /// [`RunningDataflow::recompose`] and the failure detector's
    /// repairs.
    pub(crate) fn recompose(
        &self,
        delta: &GraphDelta,
    ) -> Result<RecomposeStats> {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        let engine = crate::recompose::engine::RecomposeEngine::new(self);
        let stats = engine.execute(delta)?;
        self.recompose_log
            .lock()
            .expect("recompose log poisoned")
            .push(stats.clone());
        Ok(stats)
    }

    /// Checkpoint every flake hosted on a live container into the
    /// checkpoint store (the periodic tick of the failure detector, and
    /// [`RunningDataflow::checkpoint_now`]).  Holds the recompose gate:
    /// a checkpoint pauses/resumes its flake, which must never
    /// interleave with a surgery's own quiesce.  Returns how many
    /// flakes were captured; per-flake failures are logged and
    /// skipped (the previous checkpoint stays in the store).
    pub(crate) fn checkpoint_all(&self) -> usize {
        let _gate =
            self.recompose_gate.lock().expect("recompose gate poisoned");
        let targets: Vec<(String, Arc<Flake>, bool)> = {
            let topo = self.topo.read().expect("topology poisoned");
            topo.flakes
                .iter()
                .map(|(id, f)| {
                    let dead = topo
                        .containers
                        .get(id)
                        .map(|c| c.is_dead())
                        .unwrap_or(true);
                    (id.clone(), Arc::clone(f), dead)
                })
                .collect()
        };
        let mut captured = 0;
        for (id, flake, dead) in targets {
            if dead {
                continue; // a crashed flake cannot quiesce
            }
            match flake.checkpoint() {
                Ok(cp) => {
                    let queued: usize =
                        cp.queued.values().map(Vec::len).sum();
                    crate::telemetry::ctr_checkpoints().inc();
                    crate::telemetry::ctr_checkpoint_messages()
                        .add(queued as u64);
                    self.checkpoints
                        .lock()
                        .expect("checkpoint store poisoned")
                        .insert(id, cp);
                    captured += 1;
                }
                Err(e) => crate::log_warn!(
                    "checkpoint of '{id}' failed: {e}"
                ),
            }
        }
        captured
    }

    /// Pellet ids currently placed on container `cid`.
    pub(crate) fn flakes_on_container(&self, cid: &str) -> Vec<String> {
        let topo = self.topo.read().expect("topology poisoned");
        let mut ids: Vec<String> = topo
            .containers
            .iter()
            .filter(|(_, c)| c.id == cid)
            .map(|(pid, _)| pid.clone())
            .collect();
        ids.sort();
        ids
    }

    /// Re-home every flake stranded on dead container `cid` via one
    /// `ReplaceFailed` delta, then evict the container's VM.  An error
    /// (typically a version race with a concurrent user surgery) leaves
    /// the container pending; the detector retries next tick.
    pub(crate) fn repair_dead_container(&self, cid: &str) -> Result<()> {
        let (version, stranded) = {
            let topo = self.topo.read().expect("topology poisoned");
            let mut ids: Vec<String> = topo
                .containers
                .iter()
                .filter(|(_, c)| c.id == cid)
                .map(|(pid, _)| pid.clone())
                .collect();
            ids.sort();
            (topo.graph.version, ids)
        };
        if !stranded.is_empty() {
            let mut delta = GraphDelta::new(version);
            for id in &stranded {
                delta.replace_failed(id);
            }
            self.recompose(&delta)?;
        }
        if let Err(e) = self.manager.evict(cid) {
            crate::log_warn!(
                "evict of dead container '{cid}' failed: {e}"
            );
        }
        Ok(())
    }

    pub(crate) fn record_failure(&self, ev: FailureEvent) {
        self.failures
            .lock()
            .expect("failure log poisoned")
            .push(ev);
    }

    pub(crate) fn record_repair(&self, ev: RepairEvent) {
        crate::telemetry::ctr_replayed().add(ev.replayed as u64);
        self.repairs.lock().expect("repair log poisoned").push(ev);
    }

    fn failures(&self) -> Vec<FailureEvent> {
        self.failures.lock().expect("failure log poisoned").clone()
    }

    fn repairs(&self) -> Vec<RepairEvent> {
        self.repairs.lock().expect("repair log poisoned").clone()
    }
}

/// A launched continuous dataflow.
pub struct RunningDataflow {
    pub(crate) inner: Arc<DataflowInner>,
    monitor: Mutex<Option<Monitor>>,
    detector: Mutex<Option<FailureDetector>>,
}

impl RunningDataflow {
    /// The container hosting a pellet's flake (for manual core regrants).
    pub fn container(
        &self,
        pellet_id: &str,
    ) -> Result<Arc<crate::container::Container>> {
        self.inner.container(pellet_id)
    }

    /// The flake executing a pellet.
    pub fn flake(&self, pellet_id: &str) -> Result<Arc<Flake>> {
        self.inner.flake(pellet_id)
    }

    pub fn pellet_ids(&self) -> Vec<String> {
        self.inner
            .topo
            .read()
            .expect("topology poisoned")
            .flakes
            .keys()
            .cloned()
            .collect()
    }

    /// A snapshot of the current (versioned) graph.
    pub fn graph(&self) -> DataflowGraph {
        self.inner.graph()
    }

    /// Current topology version (bumped by every applied delta).
    pub fn graph_version(&self) -> u64 {
        self.inner.topo.read().expect("topology poisoned").graph.version
    }

    /// The dataflow's authoritative logical → physical endpoint table.
    /// Remote senders hold this (plus a `floe://<flake>/<port>`
    /// address) instead of a socket address, so they follow flake
    /// relocations automatically.
    pub fn endpoints(&self) -> Arc<EndpointTable> {
        Arc::clone(
            &self.inner.topo.read().expect("topology poisoned").endpoints,
        )
    }

    /// The resource manager this dataflow allocates from.
    pub(crate) fn manager(&self) -> &Arc<ResourceManager> {
        &self.inner.manager
    }

    /// Bind a TCP ingress endpoint (`127.0.0.1:port`, 0 = ephemeral)
    /// for a pellet's input ports and record it under the pellet's
    /// logical address.  Returns the bound `host:port`.  The fed flake
    /// stays fully relocatable: connect with
    /// `TcpSender::logical(run.endpoints(), &EndpointAddr::new(id,
    /// port))` and the sender rebinds across moves.
    pub fn serve_tcp(&self, pellet_id: &str, port: u16) -> Result<String> {
        self.inner.flake(pellet_id)?.serve_tcp(port)
    }

    /// Inject a message into a source pellet's input port (the paper's
    /// "initial inputs" entry point returned by the coordinator).
    ///
    /// The flake is resolved under the topology read lock, but the
    /// (possibly blocking) queue push happens after the lock is
    /// dropped, so backpressure on a paused pellet can never deadlock
    /// an in-flight surgery.  If the resolved flake was torn down
    /// mid-push (relocation closes the old queues behind its capture),
    /// the inject re-resolves and retries, which preserves
    /// per-producer FIFO: the retried message lands after the captured
    /// backlog was replayed into the replacement.
    pub fn inject(
        &self,
        pellet_id: &str,
        port: &str,
        msg: Message,
    ) -> Result<()> {
        // The retry copy is an Arc bump (payloads are shared), not a
        // payload clone; the final attempt moves the message.
        const ATTEMPTS: usize = 8;
        for _ in 0..ATTEMPTS - 1 {
            let flake = self.inner.flake(pellet_id)?;
            match flake.inject(port, msg.clone()) {
                Ok(()) => return Ok(()),
                // Only a closed input queue is transient (the flake is
                // being replaced); anything else — unknown port, bad
                // pellet — is permanent and surfaces immediately.
                Err(FloeError::Channel(_)) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e),
            }
        }
        self.inner.flake(pellet_id)?.inject(port, msg)
    }

    /// Wait for all flakes to drain (tests, graceful stop).  The idle
    /// condition must hold across consecutive checks because a message
    /// can transiently be in neither a queue nor an in-flight counter
    /// while a thread moves it between flakes.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut idle_streak = 0;
        loop {
            let busy = self.inner.flake_snapshot().iter().any(|f| {
                f.queue_len() > 0
                    || f.ready_len() > 0
                    || f.probes()
                        .inflight
                        .load(std::sync::atomic::Ordering::SeqCst)
                        > 0
            });
            if !busy {
                idle_streak += 1;
                if idle_streak >= 3 {
                    return true;
                }
            } else {
                idle_streak = 0;
            }
            if std::time::Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// **Dynamic task update** by pellet id: re-resolve the pellet's class
    /// (or a new class) in the registry and swap in place (§II-B).
    pub fn update_pellet(
        &self,
        pellet_id: &str,
        new_class: Option<&str>,
        sync: bool,
        landmark: bool,
    ) -> Result<u64> {
        let _gate = self
            .inner
            .recompose_gate
            .lock()
            .expect("recompose gate poisoned");
        let flake = self.inner.flake(pellet_id)?;
        let class = new_class.unwrap_or_else(|| flake.class());
        let factory = self.inner.registry.resolve(class)?;
        flake.update_pellet(factory, sync, landmark)
    }

    /// **Dynamic dataflow (sub-graph) update**: update several pellets in a
    /// coordinated manner — all intake paused, all swapped, all resumed —
    /// so downstream pellets see a consistent cut-over (§II-B).
    pub fn update_subgraph(
        &self,
        updates: &[(String, String)],
        landmark: bool,
    ) -> Result<()> {
        let _gate = self
            .inner
            .recompose_gate
            .lock()
            .expect("recompose gate poisoned");
        // Validate everything first so we never pause on a bad request.
        let mut planned = Vec::new();
        for (pellet_id, class) in updates {
            let flake = self.inner.flake(pellet_id)?;
            let factory = self.inner.registry.resolve(class)?;
            planned.push((flake, factory));
        }
        for (flake, _) in &planned {
            flake.pause();
        }
        let result: Result<()> = (|| {
            for (flake, factory) in &planned {
                // Synchronous per-flake swap; intake already paused for the
                // whole sub-graph, so the slowest drain gates the cut-over.
                flake.update_pellet(Arc::clone(factory), true, landmark)?;
            }
            Ok(())
        })();
        for (flake, _) in &planned {
            flake.resume();
        }
        result
    }

    /// **Cascading "wave" update** (§II-B future work, implemented):
    /// updates pellets one by one in upstream→downstream order, emitting an
    /// Update landmark at each hop, so a clear wavefront separates
    /// pre-update from post-update streams without a global pause.
    ///
    /// Every pellet id is validated and every class resolved *before*
    /// the first swap: a bad entry anywhere in the update set fails the
    /// whole wave up front instead of leaving upstream flakes updated
    /// and the rest untouched.
    pub fn wave_update(
        &self,
        updates: &[(String, String)],
    ) -> Result<Vec<u64>> {
        let _gate = self
            .inner
            .recompose_gate
            .lock()
            .expect("recompose gate poisoned");
        let order = self.inner.graph().wiring_order()?; // downstream-first
        let mut planned = Vec::new();
        // Reverse = upstream-first traversal of the sub-graph.
        for id in order.iter().rev() {
            if let Some((_, class)) =
                updates.iter().find(|(p, _)| p == id)
            {
                let flake = self.inner.flake(id)?;
                let factory = self.inner.registry.resolve(class)?;
                planned.push((flake, factory));
            }
        }
        if planned.len() != updates.len() {
            return Err(FloeError::Graph(
                "wave_update: some pellets not in graph".into(),
            ));
        }
        let mut versions = Vec::new();
        for (flake, factory) in planned {
            versions.push(flake.update_pellet(factory, true, true)?);
        }
        Ok(versions)
    }

    /// **Live graph surgery** (§II-B "dynamic recomposition"): apply a
    /// [`GraphDelta`] — add/remove pellets and edges, splice a pellet
    /// into a live edge, retarget edges, relocate flakes across
    /// containers, replace failed flakes — while the stream keeps
    /// flowing.  See [`crate::recompose`] for semantics and
    /// guarantees.  Surgeries are serialized per dataflow; the
    /// returned [`RecomposeStats`] reports the measured
    /// pause-to-resume downtime.
    pub fn recompose(&self, delta: &GraphDelta) -> Result<RecomposeStats> {
        self.inner.recompose(delta)
    }

    /// Checkpoint every flake into the in-memory store a later repair
    /// restores from (the synchronous twin of the periodic
    /// `checkpoint_interval` tick).  Returns how many flakes were
    /// captured.
    pub fn checkpoint_now(&self) -> usize {
        self.inner.checkpoint_all()
    }

    /// Release every container no flake lives in back to the cloud
    /// (scale-in).  Serialized with surgeries via the recompose gate:
    /// a concurrent relocation's freshly allocated — still empty —
    /// container can never be swept out from under the engine between
    /// placement and spawn.  Returns how many containers were
    /// released.
    pub fn release_idle_containers(&self) -> Result<usize> {
        let _gate = self
            .inner
            .recompose_gate
            .lock()
            .expect("recompose gate poisoned");
        self.inner.manager.release_idle()
    }

    /// Every applied surgery with its measured downtime, oldest first.
    pub fn recompose_history(&self) -> Vec<RecomposeStats> {
        self.inner
            .recompose_log
            .lock()
            .expect("recompose log poisoned")
            .clone()
    }

    /// Snapshot of the adaptation monitor's decision history (the live
    /// Fig. 4 series); empty when no adaptation was configured.
    pub fn adaptation_history(
        &self,
    ) -> Vec<crate::adaptation::AdaptationSample> {
        self.monitor
            .lock()
            .expect("monitor poisoned")
            .as_ref()
            .map(|m| m.history().snapshot())
            .unwrap_or_default()
    }

    /// Container failures detected by the lease detector, oldest first.
    pub fn failures(&self) -> Vec<FailureEvent> {
        self.inner.failures()
    }

    /// Flakes re-homed by `ReplaceFailed` repairs, oldest first.
    pub fn repairs(&self) -> Vec<RepairEvent> {
        self.inner.repairs()
    }

    /// Typed aggregated stats (see [`DataflowStats`]).
    pub fn stats(&self) -> DataflowStats {
        let t = self.inner.clock.now();
        let (graph_name, graph_version, flakes, endpoints) = {
            let topo =
                self.inner.topo.read().expect("topology poisoned");
            let flakes: Vec<(String, Arc<Flake>)> = topo
                .flakes
                .iter()
                .map(|(id, f)| (id.clone(), Arc::clone(f)))
                .collect();
            (
                topo.graph.name.clone(),
                topo.graph.version,
                flakes,
                Arc::clone(&topo.endpoints),
            )
        };
        let mut pellets = Vec::new();
        for (id, f) in &flakes {
            let obs = f.observe(t);
            pellets.push(PelletStats {
                id: id.clone(),
                class: f.class().to_string(),
                cores: obs.cores,
                instances: obs.instances,
                queue: obs.queue_len,
                arrival_rate: obs.arrival_rate,
                latency: obs.service_latency,
                selectivity: obs.selectivity,
                version: f.version(),
            });
        }
        DataflowStats {
            graph: graph_name,
            graph_version,
            recomposes: self
                .inner
                .recompose_log
                .lock()
                .expect("recompose log poisoned")
                .len(),
            endpoints: EndpointInfo {
                version: endpoints.version(),
                published: endpoints.published(),
            },
            t,
            pellets,
            failures: self.inner.failures(),
            repairs: self.inner.repairs(),
            telemetry: crate::telemetry::metrics()
                .histogram_summaries(),
        }
    }

    /// Aggregated stats document (served by the coordinator endpoint);
    /// the JSON form of [`RunningDataflow::stats`].
    pub fn stats_json(&self) -> Json {
        self.stats().to_json()
    }

    /// Stop the control loops and all flakes.
    pub fn stop(&self) {
        // Detector first: it must not race shutdown by mistaking
        // deliberately stopped flakes for failures mid-teardown.
        if let Some(mut d) =
            self.detector.lock().expect("detector poisoned").take()
        {
            d.stop();
        }
        if let Some(mut m) =
            self.monitor.lock().expect("monitor poisoned").take()
        {
            m.stop();
        }
        let (order, flakes, containers) = {
            let topo =
                self.inner.topo.read().expect("topology poisoned");
            (
                topo.graph.wiring_order(),
                topo.flakes.clone(),
                topo.containers.clone(),
            )
        };
        // Stop sources first (wiring order reversed = sources first), so
        // downstream flakes drain naturally before shutdown.
        if let Ok(order) = order {
            for id in order.iter().rev() {
                if let Some(f) = flakes.get(id) {
                    f.shutdown();
                }
            }
        }
        for f in flakes.values() {
            f.shutdown();
        }
        for c in containers.values() {
            c.stop_heartbeat();
        }
    }
}

impl Drop for RunningDataflow {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The coordinator.
pub struct Coordinator {
    manager: Arc<ResourceManager>,
    registry: PelletRegistry,
}

impl Coordinator {
    pub fn new(
        manager: Arc<ResourceManager>,
        registry: PelletRegistry,
    ) -> Coordinator {
        Coordinator { manager, registry }
    }

    /// Parse, place, wire (bottom-up BFS ignoring loops) and activate a
    /// graph.  Returns the running dataflow handle with ingress access.
    ///
    /// Accepts anything convertible into [`RuntimeOptions`], which
    /// keeps the deprecated [`LaunchOptions`] working for one release.
    pub fn launch(
        &self,
        graph: DataflowGraph,
        options: impl Into<RuntimeOptions>,
    ) -> Result<RunningDataflow> {
        let options: RuntimeOptions = options.into();
        if let Some(cfg) = options.telemetry {
            crate::telemetry::configure(cfg);
        }
        graph.validate()?;
        let order = graph.wiring_order()?;
        crate::log_info!(
            "coordinator: launching '{}' ({} pellets), wiring order {:?}",
            graph.name,
            graph.pellets.len(),
            order
        );
        let tuning = FlakeTuning::from_options(&options);

        // 1. Instantiate flakes bottom-up so every sink exists before any
        //    upstream pellet could emit, publishing each flake's input
        //    ports into the dataflow's endpoint table as it spawns.
        let endpoints = EndpointTable::new();
        let mut flakes: HashMap<String, Arc<Flake>> = HashMap::new();
        let mut containers = HashMap::new();
        for id in &order {
            let spec = graph
                .pellet(id)
                .ok_or_else(|| {
                    FloeError::Graph(format!("missing pellet '{id}'"))
                })?
                .clone();
            let factory = self.registry.resolve(&spec.class)?;
            let mut cfg = FlakeConfig::from_spec(&spec);
            tuning.apply(&mut cfg);
            let container = self.manager.allocate(cfg.cores)?;
            let flake = container.spawn_flake(cfg, factory)?;
            flake.publish_endpoints(&endpoints);
            containers.insert(id.clone(), Arc::clone(&container));
            flakes.insert(id.clone(), flake);
        }

        // 2. Wire edges, still bottom-up by source pellet.  Edges are
        //    *logical*: each transport holds the sink's
        //    `floe://<flake>/<port>` address and resolves it through
        //    the versioned endpoint table per send, so a later
        //    relocation republishes the sink and every edge follows
        //    without rewiring.  The sink's port is still validated
        //    eagerly — a bad edge fails the launch, not the stream.
        for id in &order {
            let spec = graph.pellet(id).expect("validated");
            for out in &spec.outputs {
                for edge in graph.edges_from(id, &out.name) {
                    let sink = &flakes[&edge.to_pellet];
                    sink.input_queue(&edge.to_port)?; // validate
                    let transport: Arc<dyn Transport> =
                        Arc::new(EndpointTransport::new(
                            Arc::clone(&endpoints),
                            EndpointAddr::new(
                                edge.to_pellet.clone(),
                                edge.to_port.clone(),
                            ),
                            format!(
                                "{}.{} -> {}.{}",
                                edge.from_pellet,
                                edge.from_port,
                                edge.to_pellet,
                                edge.to_port
                            ),
                        ));
                    flakes[id].wire_output(&out.name, transport)?;
                }
            }
        }

        let clock: Arc<dyn Clock> = Arc::new(WallClock::new());
        let topo = Arc::new(RwLock::new(Topology {
            graph,
            flakes,
            containers,
            endpoints,
        }));

        // 3. Optional adaptation monitor.  Entries are pellet *ids*
        //    discovered from the shared topology on every tick, so
        //    later graph surgery re-binds relocated flakes, drops
        //    removed ones, and auto-watches newly added pellets (see
        //    `FlakeDirectory` / `Monitor::start_auto`).
        let monitor = options.adaptation.map(|setup| {
            Monitor::start_auto(
                setup.make,
                Arc::clone(&topo) as Arc<dyn FlakeDirectory>,
                Arc::clone(&clock),
                setup.interval,
            )
        });

        let inner = Arc::new(DataflowInner {
            topo,
            registry: self.registry.clone(),
            manager: Arc::clone(&self.manager),
            tuning,
            clock,
            recompose_gate: Mutex::new(()),
            recompose_log: Mutex::new(Vec::new()),
            checkpoints: Mutex::new(HashMap::new()),
            failures: Mutex::new(Vec::new()),
            repairs: Mutex::new(Vec::new()),
        });

        // 4. Optional fault tolerance: heartbeat every launch
        //    container (later containers are adopted by the detector
        //    on first sight) and start the lease ticker.
        let detector = options.fault_tolerance.map(|ft| {
            let topo = inner.topo.read().expect("topology poisoned");
            for c in topo.containers.values() {
                c.start_heartbeat(ft.heartbeat_interval());
            }
            drop(topo);
            FailureDetector::start(Arc::clone(&inner), ft)
        });

        Ok(RunningDataflow {
            inner,
            monitor: Mutex::new(monitor),
            detector: Mutex::new(detector),
        })
    }

    pub fn registry(&self) -> &PelletRegistry {
        &self.registry
    }

    pub fn manager(&self) -> &Arc<ResourceManager> {
        &self.manager
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, SplitMode};
    use crate::manager::SimulatedCloud;
    use crate::pellet::builtins::CollectSink;
    use std::sync::Mutex as StdMutex;

    fn coordinator() -> Coordinator {
        let cloud = SimulatedCloud::new(256, Duration::ZERO);
        let mgr = ResourceManager::new(cloud);
        Coordinator::new(mgr, PelletRegistry::with_builtins())
    }

    fn collect_class(
        reg: &PelletRegistry,
        class: &str,
    ) -> Arc<StdMutex<Vec<Message>>> {
        let sink = Arc::new(StdMutex::new(Vec::new()));
        let s2 = Arc::clone(&sink);
        reg.register(class, move || {
            Box::new(CollectSink { collected: Arc::clone(&s2) })
        });
        sink
    }

    #[test]
    fn launch_linear_pipeline_end_to_end() {
        let coord = coordinator();
        let sink = collect_class(coord.registry(), "test.Collect");

        let mut g = GraphBuilder::new("lin");
        g.pellet("up", "floe.builtin.Uppercase")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        g.pellet("sink", "test.Collect").in_port("in");
        g.edge("up", "out", "sink", "in");
        let run = coord
            .launch(g.build().unwrap(), RuntimeOptions::new())
            .unwrap();

        for i in 0..20 {
            run.inject("up", "in", Message::text(format!("m{i}"))).unwrap();
        }
        assert!(run.drain(Duration::from_secs(5)));
        let got = sink.lock().unwrap();
        assert_eq!(got.len(), 20);
        assert!(got.iter().all(|m| m.as_text().unwrap().starts_with('M')));
        drop(got);
        run.stop();
    }

    #[test]
    fn launch_rejects_unknown_class() {
        let coord = coordinator();
        let mut g = GraphBuilder::new("bad");
        g.pellet("x", "no.such.Class");
        let err =
            coord.launch(g.build().unwrap(), RuntimeOptions::new());
        assert!(err.is_err());
    }

    #[test]
    fn stats_json_lists_pellets() {
        let coord = coordinator();
        let mut g = GraphBuilder::new("s");
        g.pellet("id1", "floe.builtin.Identity")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        let run = coord
            .launch(g.build().unwrap(), RuntimeOptions::new())
            .unwrap();
        let stats = run.stats_json();
        assert_eq!(
            stats.get("graph").unwrap().as_str().unwrap(),
            "s"
        );
        assert_eq!(
            stats.get("graph_version").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            stats.get("pellets").unwrap().as_arr().unwrap().len(),
            1
        );
        // The typed form agrees with the document it serializes to.
        let typed = run.stats();
        assert_eq!(typed.graph, "s");
        assert_eq!(typed.pellets.len(), 1);
        assert!(typed.failures.is_empty());
        assert!(typed.repairs.is_empty());
        run.stop();
    }

    // The one deliberately deprecated call site: the shim must keep
    // compiling (and behaving) for one release.
    #[test]
    #[allow(deprecated)]
    fn launch_options_shim_still_launches() {
        let coord = coordinator();
        let mut g = GraphBuilder::new("shim");
        g.pellet("id1", "floe.builtin.Identity")
            .in_port("in")
            .out_port("out", SplitMode::RoundRobin);
        let run = coord
            .launch(g.build().unwrap(), LaunchOptions::default())
            .unwrap();
        run.inject("id1", "in", Message::text("x")).unwrap();
        assert!(run.drain(Duration::from_secs(5)));
        let opts: RuntimeOptions = LaunchOptions::default().into();
        assert!(!opts.dedup);
        assert!(opts.fault_tolerance.is_none());
        run.stop();
    }

    #[test]
    fn runtime_options_builder_composes() {
        let opts = RuntimeOptions::new()
            .alpha(2)
            .batch_size(64)
            .input_shards(1)
            .backend(ChannelBackend::Mutex)
            .dedup(true)
            .checkpoint_interval(Duration::from_millis(250));
        assert_eq!(opts.alpha, 2);
        assert_eq!(opts.batch_size, 64);
        assert_eq!(opts.input_shards, 1);
        assert!(opts.dedup);
        let ft = opts.fault_tolerance.expect("ft enabled");
        assert_eq!(
            ft.checkpoint_interval,
            Some(Duration::from_millis(250))
        );
        // Default lease knobs came along with the convenience setter.
        assert_eq!(ft.lease_missed_k, 3);
    }
}
