//! Fault tolerance: lease-based failure detection and automatic
//! repair (ROADMAP item 4, grounded in Shukla & Simmhan, "Toward
//! Reliable and Rapid Elasticity for Streaming Dataflows on Clouds").
//!
//! Three cooperating pieces:
//!
//! * **Heartbeats** — every [`crate::container::Container`] under a
//!   fault-tolerant launch runs a heartbeat thread bumping a monotonic
//!   counter.  A crash ([`crate::container::Container::kill`]) freezes
//!   the counter, exactly like a dead remote agent going silent.
//! * **Leases** — the coordinator-side [`FailureDetector`] ticker
//!   samples every container's counter each `lease_interval`; a
//!   counter that does not advance for `lease_missed_k` consecutive
//!   samples expires its lease and the container is declared dead.
//!   The pure sampling logic lives in [`LeaseTracker`] so it can be
//!   property-tested without threads.
//! * **Repair** — a dead container's flakes are re-spawned through a
//!   [`crate::recompose::DeltaOp::ReplaceFailed`] recomposition: the
//!   engine places replacements on surviving (or freshly provisioned)
//!   containers via `allocate_avoiding`, restores each from its last
//!   periodic checkpoint, and republishes its logical endpoints so
//!   every sender — in-process edge or remote TCP peer — re-resolves
//!   and re-routes automatically.  The detector then evicts the dead
//!   container's VM.
//!
//! The detector also drives **periodic checkpointing**: every
//! `checkpoint_interval` it snapshots each live flake (state + dedup
//! watermarks + buffered input) into the dataflow's checkpoint store,
//! bounding what a crash can lose to one interval.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use super::DataflowInner;
use crate::container::Container;
use crate::util::json::Json;

/// One sender-side stall report: a logical TCP sender (or a live
/// endpoint wait) exhausted its repair-bridging deadline against
/// `target` — the symmetric-partition signal the lease path cannot
/// see on its own (a partitioned container's heartbeat thread is
/// in-process here, so its lease never expires; the *senders* are who
/// notice).
#[derive(Debug, Clone)]
pub struct StallReport {
    /// The unreachable flake / endpoint label.
    pub target: String,
    /// Human-readable cause (last send error, deadline).
    pub detail: String,
}

fn stall_registry() -> &'static Mutex<Vec<StallReport>> {
    static REG: OnceLock<Mutex<Vec<StallReport>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Record that `target` stayed unreachable past a sender's full retry
/// deadline.  Called from the channel layer; drained by the failure
/// detector each tick, which logs and traces the suspicion.  Cheap
/// and non-blocking enough for a send error path.
pub fn report_endpoint_stall(target: &str, detail: &str) {
    crate::telemetry::ctr_endpoint_stalls().inc();
    let mut reg =
        stall_registry().lock().unwrap_or_else(|e| e.into_inner());
    // Bounded: a hot broken link must not grow this without limit
    // between detector ticks (or in runs with no detector at all).
    if reg.len() < 1024 {
        reg.push(StallReport {
            target: target.to_string(),
            detail: detail.to_string(),
        });
    }
}

/// Drain every stall reported since the last call.
pub(crate) fn drain_endpoint_stalls() -> Vec<StallReport> {
    let mut reg =
        stall_registry().lock().unwrap_or_else(|e| e.into_inner());
    std::mem::take(&mut *reg)
}

/// Fault-tolerance knobs (set through
/// [`crate::coordinator::RuntimeOptions::fault_tolerance`]).
#[derive(Debug, Clone, Copy)]
pub struct FaultToleranceConfig {
    /// Detector sampling period (one lease tick).
    pub lease_interval: Duration,
    /// Consecutive samples without a heartbeat advance before a
    /// container's lease expires and it is declared dead.
    pub lease_missed_k: u32,
    /// Periodic checkpoint period; `None` disables periodic
    /// checkpoints (repair then restores whatever
    /// [`crate::coordinator::RunningDataflow::checkpoint_now`] last
    /// captured, or starts fresh).
    pub checkpoint_interval: Option<Duration>,
}

impl Default for FaultToleranceConfig {
    fn default() -> Self {
        FaultToleranceConfig {
            lease_interval: Duration::from_millis(50),
            lease_missed_k: 3,
            checkpoint_interval: None,
        }
    }
}

impl FaultToleranceConfig {
    /// Containers beat several times per lease tick so a healthy
    /// heartbeat thread always advances the counter between samples.
    pub(crate) fn heartbeat_interval(&self) -> Duration {
        (self.lease_interval / 4).max(Duration::from_millis(1))
    }
}

#[derive(Debug, Clone, Copy)]
struct LeaseState {
    beat: u64,
    misses: u32,
    dead: bool,
}

/// Pure lease bookkeeping: feed it one heartbeat sample per container
/// per tick; it reports lease expiry exactly once per container.
///
/// No false positive while heartbeats flow: any advance of the counter
/// between samples resets the miss count.  Detection is prompt: a
/// counter frozen at tick `T` expires its lease by tick
/// `T + lease_missed_k` (the property tests in `tests/props.rs` pin
/// both bounds).
pub struct LeaseTracker {
    missed_k: u32,
    seen: HashMap<String, LeaseState>,
}

impl LeaseTracker {
    pub fn new(missed_k: u32) -> LeaseTracker {
        LeaseTracker { missed_k: missed_k.max(1), seen: HashMap::new() }
    }

    /// Record one sample of `id`'s heartbeat counter.  Returns `true`
    /// exactly once: on the sample that expires the lease.
    pub fn observe(&mut self, id: &str, beat: u64) -> bool {
        match self.seen.get_mut(id) {
            None => {
                // First sight is the baseline, never a miss.
                self.seen.insert(
                    id.to_string(),
                    LeaseState { beat, misses: 0, dead: false },
                );
                false
            }
            Some(st) => {
                if st.dead {
                    return false;
                }
                if beat != st.beat {
                    st.beat = beat;
                    st.misses = 0;
                    return false;
                }
                st.misses += 1;
                if st.misses >= self.missed_k {
                    st.dead = true;
                    return true;
                }
                false
            }
        }
    }

    /// Whether `id`'s lease has expired.
    pub fn is_dead(&self, id: &str) -> bool {
        self.seen.get(id).map(|s| s.dead).unwrap_or(false)
    }

    /// Drop all state for `id` (after its container was evicted).
    pub fn forget(&mut self, id: &str) {
        self.seen.remove(id);
    }
}

/// One detected container failure (see
/// [`crate::coordinator::RunningDataflow::failures`]).
#[derive(Debug, Clone)]
pub struct FailureEvent {
    /// The dead container.
    pub container: String,
    /// Pellets stranded on it at detection time.
    pub flakes: Vec<String>,
    /// Detector tick (multiples of `lease_interval` since launch) at
    /// which the lease expired.
    pub detected_at_tick: u64,
}

impl FailureEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("container", Json::str(self.container.clone())),
            (
                "flakes",
                Json::Arr(
                    self.flakes
                        .iter()
                        .map(|f| Json::str(f.clone()))
                        .collect(),
                ),
            ),
            (
                "detected_at_tick",
                Json::num(self.detected_at_tick as f64),
            ),
        ])
    }
}

/// One repaired flake (see
/// [`crate::coordinator::RunningDataflow::repairs`]).
#[derive(Debug, Clone)]
pub struct RepairEvent {
    /// The re-spawned pellet.
    pub flake: String,
    /// The dead container it was stranded on.
    pub from_container: String,
    /// The surviving / freshly provisioned container now hosting it.
    pub to_container: String,
    /// Whether a checkpoint existed to restore from (false = the
    /// replacement started with fresh state).
    pub restored_from_checkpoint: bool,
    /// Buffered input messages replayed out of the checkpoint.
    pub replayed: usize,
}

impl RepairEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flake", Json::str(self.flake.clone())),
            ("from", Json::str(self.from_container.clone())),
            ("to", Json::str(self.to_container.clone())),
            (
                "restored_from_checkpoint",
                Json::Bool(self.restored_from_checkpoint),
            ),
            ("replayed", Json::num(self.replayed as f64)),
        ])
    }
}

/// Coordinator-side ticker thread (the failure-detection sibling of
/// [`crate::adaptation::Monitor`]): samples heartbeats, expires
/// leases, drives periodic checkpoints, and executes repairs through
/// the gated recompose path.
pub(crate) struct FailureDetector {
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl FailureDetector {
    pub(crate) fn start(
        inner: Arc<DataflowInner>,
        cfg: FaultToleranceConfig,
    ) -> FailureDetector {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = thread::Builder::new()
            .name("floe-failure-detector".into())
            .spawn(move || detector_loop(&inner, cfg, &stop2))
            .expect("spawn failure detector");
        FailureDetector { stop, join: Some(join) }
    }

    pub(crate) fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for FailureDetector {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Distinct live containers currently hosting flakes.
fn container_snapshot(
    inner: &DataflowInner,
) -> HashMap<String, Arc<Container>> {
    let topo = inner.topo.read().expect("topology poisoned");
    let mut out = HashMap::new();
    for c in topo.containers.values() {
        out.entry(c.id.clone()).or_insert_with(|| Arc::clone(c));
    }
    out
}

fn detector_loop(
    inner: &DataflowInner,
    cfg: FaultToleranceConfig,
    stop: &AtomicBool,
) {
    let mut tracker = LeaseTracker::new(cfg.lease_missed_k);
    let mut tick: u64 = 0;
    let mut last_checkpoint = Instant::now();
    // Dead containers whose flakes still await repair (a repair delta
    // that loses a version race with a concurrent surgery simply
    // retries on the next tick), with the instant the lease expired so
    // the eventual repair can record detection-to-heal latency.
    let mut pending: Vec<(String, Instant)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        thread::sleep(cfg.lease_interval);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        tick += 1;

        // Surface sender-reported endpoint stalls (suspected
        // partitions).  Surfacing only — the lease path stays the
        // single authority on declaring death, because a stall report
        // can be a sender-side problem (e.g. its own link) and a
        // forced kill on it would turn one slow link into an outage.
        for stall in drain_endpoint_stalls() {
            crate::log_warn!(
                "failure detector: endpoint '{}' suspected \
                 partitioned: {}",
                stall.target,
                stall.detail
            );
            crate::telemetry::tracelog().instant(
                "suspect",
                &stall.target,
                &stall.detail,
            );
        }

        // Periodic checkpoints, serialized with surgeries (the store
        // is what a later repair restores from).
        if let Some(interval) = cfg.checkpoint_interval {
            if last_checkpoint.elapsed() >= interval {
                inner.checkpoint_all();
                last_checkpoint = Instant::now();
            }
        }

        // Sample every container's heartbeat.  Containers provisioned
        // after launch (elastic scale-out, repairs) are adopted here:
        // `start_heartbeat` is an idempotent no-op on a beating or
        // dead container, and the tracker baselines them on first
        // sight.
        let containers = container_snapshot(inner);
        for (cid, c) in &containers {
            if pending.iter().any(|(p, _)| p == cid) {
                continue;
            }
            c.start_heartbeat(cfg.heartbeat_interval());
            if tracker.observe(cid, c.heartbeat()) {
                c.mark_dead();
                let flakes = inner.flakes_on_container(cid);
                crate::log_warn!(
                    "failure detector: container '{cid}' missed \
                     {} lease(s); declaring dead ({} flake(s) \
                     stranded)",
                    cfg.lease_missed_k,
                    flakes.len()
                );
                crate::telemetry::ctr_lease_expiries().inc();
                crate::telemetry::tracelog().instant(
                    "detect",
                    cid,
                    "lease expired",
                );
                crate::telemetry::tracelog().begin("repair", cid);
                inner.record_failure(FailureEvent {
                    container: cid.clone(),
                    flakes,
                    detected_at_tick: tick,
                });
                pending.push((cid.clone(), Instant::now()));
            }
        }

        // Repair pending containers; keep retrying across version
        // races until each one's flakes are all re-homed.
        pending.retain(|(cid, detected)| {
            match inner.repair_dead_container(cid) {
                Ok(()) => {
                    tracker.forget(cid);
                    crate::telemetry::ctr_repairs().inc();
                    crate::telemetry::hist_failover_heal()
                        .record(detected.elapsed().as_nanos() as u64);
                    crate::telemetry::tracelog()
                        .end("repair", cid, "ok");
                    false
                }
                Err(e) => {
                    crate::log_warn!(
                        "failure detector: repair of '{cid}' failed \
                         ({e}); retrying next tick"
                    );
                    true
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_tracker_baselines_then_expires() {
        let mut t = LeaseTracker::new(3);
        assert!(!t.observe("c", 7)); // baseline
        assert!(!t.observe("c", 8)); // advancing
        assert!(!t.observe("c", 8)); // miss 1
        assert!(!t.observe("c", 8)); // miss 2
        assert!(t.observe("c", 8)); // miss 3: expired
        assert!(t.is_dead("c"));
        // Expiry fires exactly once.
        assert!(!t.observe("c", 8));
        assert!(!t.observe("c", 9));
    }

    #[test]
    fn lease_tracker_advance_resets_misses() {
        let mut t = LeaseTracker::new(2);
        assert!(!t.observe("c", 1));
        assert!(!t.observe("c", 1)); // miss 1
        assert!(!t.observe("c", 2)); // advance resets
        assert!(!t.observe("c", 2)); // miss 1
        assert!(t.observe("c", 2)); // miss 2: expired
    }

    #[test]
    fn lease_tracker_forget_rebaselines() {
        let mut t = LeaseTracker::new(1);
        assert!(!t.observe("c", 5));
        assert!(t.observe("c", 5));
        t.forget("c");
        assert!(!t.observe("c", 5)); // fresh baseline, not dead
        assert!(!t.is_dead("c"));
    }
}
