//! Fluent builder for [`DataflowGraph`]s — the programmatic alternative to
//! the XML description.

use super::{
    DataflowGraph, EdgeSpec, InPortSpec, MergeMode, OutPortSpec, PelletSpec,
    SplitMode, TriggerMode, WindowSpec,
};
use crate::error::Result;

/// Builder handle for one pellet being configured.
pub struct PelletBuilder<'a> {
    spec: &'a mut PelletSpec,
}

impl<'a> PelletBuilder<'a> {
    /// Add an input port (no window).
    pub fn in_port(self, name: &str) -> Self {
        self.spec
            .inputs
            .push(InPortSpec { name: name.into(), window: WindowSpec::None });
        self
    }

    /// Add an input port with a window annotation.
    pub fn in_port_windowed(self, name: &str, window: WindowSpec) -> Self {
        self.spec.inputs.push(InPortSpec { name: name.into(), window });
        self
    }

    /// Add an output port with a split annotation.
    pub fn out_port(self, name: &str, split: SplitMode) -> Self {
        self.spec.outputs.push(OutPortSpec { name: name.into(), split });
        self
    }

    /// Static core allocation annotation.
    pub fn cores(self, n: usize) -> Self {
        self.spec.cores = Some(n);
        self
    }

    /// Mark stateful (state object survives dynamic updates).
    pub fn stateful(self) -> Self {
        self.spec.stateful = true;
        self
    }

    /// Force sequential (in-order) execution.
    pub fn sequential(self) -> Self {
        self.spec.sequential = true;
        self
    }

    /// Input merge behaviour across ports.
    pub fn merge(self, mode: MergeMode) -> Self {
        self.spec.merge = mode;
        self
    }

    /// Push or pull triggering.
    pub fn trigger(self, mode: TriggerMode) -> Self {
        self.spec.trigger = mode;
        self
    }

    /// Per-message latency hint (seconds) for the static look-ahead
    /// strategy.
    pub fn latency_hint(self, secs: f64) -> Self {
        self.spec.latency_hint = Some(secs);
        self
    }

    /// Selectivity (outputs per input) hint for the static look-ahead.
    pub fn selectivity_hint(self, ratio: f64) -> Self {
        self.spec.selectivity_hint = Some(ratio);
        self
    }
}

/// Fluent graph builder.
///
/// ```no_run
/// use floe::graph::{GraphBuilder, SplitMode};
/// let mut g = GraphBuilder::new("demo");
/// g.pellet("src", "app.Source").out_port("out", SplitMode::RoundRobin);
/// g.pellet("sink", "app.Sink").in_port("in");
/// g.edge("src", "out", "sink", "in");
/// let graph = g.build().unwrap();
/// assert_eq!(graph.pellets.len(), 2);
/// ```
pub struct GraphBuilder {
    name: String,
    pellets: Vec<PelletSpec>,
    edges: Vec<EdgeSpec>,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { name: name.into(), pellets: vec![], edges: vec![] }
    }

    /// Add a pellet and return its configuration handle.
    pub fn pellet(&mut self, id: &str, class: &str) -> PelletBuilder<'_> {
        self.pellets.push(PelletSpec::new(id, class));
        PelletBuilder { spec: self.pellets.last_mut().expect("just pushed") }
    }

    /// Wire `from.port -> to.port`.
    pub fn edge(
        &mut self,
        from: &str,
        from_port: &str,
        to: &str,
        to_port: &str,
    ) -> &mut Self {
        self.edges.push(EdgeSpec::new(from, from_port, to, to_port));
        self
    }

    /// Finish and validate.
    pub fn build(self) -> Result<DataflowGraph> {
        let g = DataflowGraph {
            name: self.name,
            pellets: self.pellets,
            edges: self.edges,
            version: 1,
        };
        g.validate()?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_annotations() {
        let mut b = GraphBuilder::new("g");
        b.pellet("p", "C")
            .in_port_windowed("in", WindowSpec::Count(5))
            .out_port("out", SplitMode::KeyHash)
            .cores(3)
            .stateful()
            .sequential()
            .merge(MergeMode::Synchronous)
            .trigger(TriggerMode::Pull)
            .latency_hint(0.25)
            .selectivity_hint(2.0);
        b.pellet("q", "C").in_port("in");
        b.edge("p", "out", "q", "in");
        // p's sync merge requires its (only) input port wired:
        b.edge("q", "out", "p", "in"); // invalid: q has no out port
        assert!(b.build().is_err());

        let mut b = GraphBuilder::new("g");
        b.pellet("p", "C")
            .out_port("out", SplitMode::KeyHash)
            .cores(3)
            .latency_hint(0.25);
        let g = b.build().unwrap();
        let p = g.pellet("p").unwrap();
        assert_eq!(p.cores, Some(3));
        assert_eq!(p.out_port("out").unwrap().split, SplitMode::KeyHash);
        assert_eq!(p.latency_hint, Some(0.25));
    }
}
